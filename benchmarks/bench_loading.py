"""Table 2: graph loading time vs node count (paper: 1M→4B nodes on 12
machines; here R-MAT scaled to the CPU container, same fixed degree 16)."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.graphstore import PartitionedGraph, generators


def main() -> None:
    for n in [62_500, 125_000, 250_000, 500_000]:
        t0 = time.perf_counter()
        g = generators.rmat(n, 16 * n, 418, seed=0)
        pg = PartitionedGraph.build(g, 4)
        dt = time.perf_counter() - t0
        emit(
            f"graph_load_n{n}",
            dt * 1e6,
            f"edges={g.n_edges};bytes={pg.memory_bytes()}",
        )


if __name__ == "__main__":
    main()
