"""Fig 10(d): query time vs label density (label-alphabet size sweep:
more labels → fewer matches per label → faster)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import avg_query_time, build_matcher, dfs_query, emit
from repro.graphstore import generators


def main(n: int = 50_000, n_queries: int = 3) -> None:
    rng = np.random.default_rng(3)
    for n_labels in [4, 16, 64, 256, 1024]:
        g = generators.rmat(n, 16 * n, n_labels, seed=6)
        m = build_matcher(g)
        qs = [q for q in (dfs_query(g, rng, 6) for _ in range(n_queries)) if q]
        t, cnt = avg_query_time(m, qs)
        emit(
            f"label_density_L{n_labels}",
            t * 1e6,
            f"label_ratio={n_labels/n:.1e};avg_matches={cnt:.0f}",
        )


if __name__ == "__main__":
    main()
