"""Fig 10(a,b): query time vs graph size — fixed degree (16) and fixed
density; the paper's headline scalability claim (time insensitive to node
count at fixed degree)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import avg_query_time, build_matcher, dfs_query, emit
from repro.graphstore import generators


def main(n_queries: int = 3) -> None:
    rng = np.random.default_rng(1)
    # fixed average degree 16 (paper Fig 10a)
    for n in [25_000, 50_000, 100_000, 200_000]:
        g = generators.rmat(n, 16 * n, 64, seed=3)
        m = build_matcher(g)
        qs = [q for q in (dfs_query(g, rng, 6) for _ in range(n_queries)) if q]
        t, cnt = avg_query_time(m, qs)
        emit(f"graph_size_fixed_degree_n{n}", t * 1e6, f"avg_matches={cnt:.0f}")

    # fixed density m = n^2 * 1e-6-ish → degree grows with n (paper Fig 10b)
    for n in [20_000, 40_000, 80_000]:
        m_edges = int(n * n * 4e-4)
        g = generators.rmat(n, m_edges, 64, seed=4)
        m = build_matcher(g)
        qs = [q for q in (dfs_query(g, rng, 6) for _ in range(n_queries)) if q]
        t, cnt = avg_query_time(m, qs)
        emit(
            f"graph_size_fixed_density_n{n}",
            t * 1e6,
            f"avg_degree={2*m_edges/n:.0f};avg_matches={cnt:.0f}",
        )


if __name__ == "__main__":
    main()
