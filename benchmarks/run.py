"""Benchmark entry point — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--fast]``
prints ``name,us_per_call,derived`` CSV rows.

``--snapshot [PATH]`` additionally writes the rows plus environment
metadata as JSON (default ``benchmarks/snapshots/BENCH_<date>.json``) —
the perf trajectory ROADMAP item 4 tracks; CI uploads a fresh snapshot as
an artifact on every run.

 paper artifact                        module
 Table 1 (index linear build/size)    bench_index
 Table 2 (graph loading)              bench_loading
 Fig 8(a,b,c) (query/edge size)       bench_query_size
 Fig 9 (speed-up vs machines)         bench_speedup
 §6.1 (pipelined first-K streaming)   bench_stream
 Fig 10(a,b) (graph size)             bench_graph_size
 Fig 10(c) (graph density)            bench_density
 Fig 10(d) (label density)            bench_label_density
 §Roofline (this brief)               bench_roofline
 Kernel backends (DESIGN.md §3)       bench_kernels
 Serving (DESIGN.md §7)               bench_serve
"""
from __future__ import annotations

import argparse
import contextlib
import datetime
import io
import json
import pathlib
import sys
import time
import traceback


def _parse_rows(suite: str, text: str) -> list[dict]:
    rows = []
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        parts = line.split(",", 2)
        if len(parts) < 2:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append({
            "suite": suite,
            "name": parts[0],
            "us_per_call": us,
            "derived": parts[2] if len(parts) > 2 else "",
        })
    return rows


def _default_snapshot_path() -> str:
    stamp = datetime.date.today().isoformat()
    return str(
        pathlib.Path(__file__).resolve().parent
        / "snapshots" / f"BENCH_{stamp}.json"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller graphs")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated suite names to run")
    ap.add_argument("--snapshot", nargs="?", const=_default_snapshot_path(),
                    default=None, metavar="PATH",
                    help="also write rows + environment metadata as JSON "
                         "(default benchmarks/snapshots/BENCH_<date>.json)")
    args = ap.parse_args()

    from benchmarks import (
        bench_density,
        bench_graph_size,
        bench_index,
        bench_kernels,
        bench_label_density,
        bench_loading,
        bench_loadset,
        bench_query_size,
        bench_roofline,
        bench_serve,
        bench_speedup,
        bench_stream,
    )

    suites = {
        "index": bench_index.main,
        "loading": bench_loading.main,
        "query_size": (lambda: bench_query_size.main(scale=0.005, n_queries=3))
        if args.fast
        else bench_query_size.main,
        "speedup": bench_speedup.main,
        "stream": bench_stream.main,
        "graph_size": bench_graph_size.main,
        "density": bench_density.main,
        "label_density": bench_label_density.main,
        "loadset": bench_loadset.main,
        "roofline": bench_roofline.main,
        "kernels": bench_kernels.main,
        "serve": (lambda: bench_serve.main(smoke=True)) if args.fast
        else bench_serve.main,
    }
    def _gc():
        # each query spec jit-compiles a fresh executable; without clearing,
        # hundreds of cached executables exhaust the JIT code allocator.
        # Executable caches are session-owned now, so dropping the suites'
        # sessions plus jax's trace caches is enough.
        import gc

        import jax

        jax.clear_caches()
        gc.collect()

    only = set(args.only.split(",")) if args.only else None
    snapshot_rows: list[dict] = []
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only is not None and name not in only:
            continue
        t0 = time.time()
        buf = io.StringIO()
        try:
            with contextlib.redirect_stdout(buf):
                fn()
        except Exception:  # noqa: BLE001 — report, keep the suite running
            buf.write(f"{name}_FAILED,0.0,\n")
            traceback.print_exc()
        text = buf.getvalue()
        sys.stdout.write(text)
        snapshot_rows.extend(_parse_rows(name, text))
        _gc()
        print(f"# suite {name} took {time.time()-t0:.1f}s", file=sys.stderr)
        sys.stdout.flush()

    if args.snapshot:
        import jax

        doc = {
            "created": datetime.datetime.now(
                datetime.timezone.utc
            ).isoformat(timespec="seconds"),
            "fast": args.fast,
            "only": args.only,
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "rows": snapshot_rows,
        }
        path = pathlib.Path(args.snapshot)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"# snapshot -> {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
