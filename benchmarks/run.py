"""Benchmark entry point — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--fast]``
prints ``name,us_per_call,derived`` CSV rows.

 paper artifact                        module
 Table 1 (index linear build/size)    bench_index
 Table 2 (graph loading)              bench_loading
 Fig 8(a,b,c) (query/edge size)       bench_query_size
 Fig 9 (speed-up vs machines)         bench_speedup
 §6.1 (pipelined first-K streaming)   bench_stream
 Fig 10(a,b) (graph size)             bench_graph_size
 Fig 10(c) (graph density)            bench_density
 Fig 10(d) (label density)            bench_label_density
 §Roofline (this brief)               bench_roofline
 Kernel backends (DESIGN.md §3)       bench_kernels
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller graphs")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()

    from benchmarks import (
        bench_density,
        bench_graph_size,
        bench_index,
        bench_kernels,
        bench_label_density,
        bench_loading,
        bench_loadset,
        bench_query_size,
        bench_roofline,
        bench_speedup,
        bench_stream,
    )

    suites = {
        "index": bench_index.main,
        "loading": bench_loading.main,
        "query_size": (lambda: bench_query_size.main(scale=0.005, n_queries=3))
        if args.fast
        else bench_query_size.main,
        "speedup": bench_speedup.main,
        "stream": bench_stream.main,
        "graph_size": bench_graph_size.main,
        "density": bench_density.main,
        "label_density": bench_label_density.main,
        "loadset": bench_loadset.main,
        "roofline": bench_roofline.main,
        "kernels": bench_kernels.main,
    }
    def _gc():
        # each query spec jit-compiles a fresh executable; without clearing,
        # hundreds of cached executables exhaust the JIT code allocator.
        # Executable caches are session-owned now, so dropping the suites'
        # sessions plus jax's trace caches is enough.
        import gc

        import jax

        jax.clear_caches()
        gc.collect()

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception:  # noqa: BLE001 — report, keep the suite running
            print(f"{name}_FAILED,0.0,", file=sys.stdout)
            traceback.print_exc()
        _gc()
        print(f"# suite {name} took {time.time()-t0:.1f}s", file=sys.stderr)
        sys.stdout.flush()


if __name__ == "__main__":
    main()
