"""Fig 10(c): query time vs graph density (average degree sweep)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    avg_query_time,
    build_matcher,
    dfs_query,
    emit,
    random_query,
)
from repro.graphstore import generators


def main(n: int = 50_000, n_queries: int = 3) -> None:
    rng = np.random.default_rng(2)
    for deg in [4, 8, 16, 32, 64]:
        g = generators.rmat(n, deg * n, 64, seed=5)
        m = build_matcher(g)
        qs = [q for q in (dfs_query(g, rng, 6) for _ in range(n_queries)) if q]
        t, cnt = avg_query_time(m, qs)
        emit(f"density_dfs_deg{deg}", t * 1e6, f"avg_matches={cnt:.0f}")
        qs = [random_query(6, 9, g.n_labels, rng) for _ in range(n_queries)]
        t, cnt = avg_query_time(m, qs)
        emit(f"density_random_deg{deg}", t * 1e6, f"avg_matches={cnt:.0f}")


if __name__ == "__main__":
    main()
