"""Continuous-batching serving vs the sequential baseline (DESIGN.md §7).

Two ways of answering the same workload on the same session:

  * **sequential** — the pre-server `launch/serve.py` loop: one
    ``session.run(q, max_matches=K, adaptive=False)`` at a time, each
    query joining its full blocked table before the next starts;
  * **server** — `QueryServer` under a Poisson open-loop load generator:
    queries arrive on exponential gaps regardless of completion (open
    loop), up to ``max_inflight`` streams stay in flight, and the
    scheduler interleaves block-join quanta, stopping each stream at its
    first-K budget — blocks past the budget are never joined.

Reported rows (``name,us_per_call,derived``):

  * ``serve_seq_query``   — us per query, sequential baseline (+ qps)
  * ``serve_cb_query``    — us per query through the server (+ qps and
    the speedup over sequential at the configured in-flight depth)
  * ``serve_cb_ttfp_p50`` / ``serve_cb_ttfp_p99`` — time-to-first-page
    percentiles (submission -> first non-empty page, queue wait included)
    against the configured per-query deadline
  * ``serve_cb_outcomes`` — served/partial/failed split and the global
    degradation count (the serving SLO: per-query degradation only)

``--hist-out PATH`` writes the full latency histogram (per-query ttfp and
wall lists, percentiles, scheduler counters) as JSON — the artifact the CI
``serve`` job uploads.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _percentile(sorted_ms: "np.ndarray", q: float) -> float:
    if len(sorted_ms) == 0:
        return float("nan")
    return float(sorted_ms[min(len(sorted_ms) - 1, int(len(sorted_ms) * q))])


def _build_workload(session, g, n_queries: int, n_shapes: int, first_k, rng):
    """``n_queries`` path queries drawn from ``n_shapes`` distinct label
    shapes — a serving mix where most arrivals hit an already-warm shape
    bucket, the way production query workloads repeat templates.

    Streams never escalate capacities, so every shape is vetted up front:
    one complete-at-fixed-caps run (shapes that overflow are discarded —
    each vetting run is a fresh jit compile, so one uniform cap config is
    tried rather than a doubling walk). Both the sequential baseline and
    the server then run at those caps."""
    from repro.workloads import path_query

    caps = {"child_cap": 32}
    shapes = []
    for _ in range(12):
        if len(shapes) >= n_shapes:
            break
        q = path_query(g, rng, 4)
        if q is None:
            continue
        r = session.run(q, max_matches=first_k, adaptive=False, **caps)
        if r.complete and r.n_matches >= first_k:
            shapes.append((q, caps))
    if not shapes:
        raise RuntimeError("no completable query shape found on this graph")
    return [shapes[i % len(shapes)] for i in range(n_queries)]


def main(smoke: bool = False, hist_out: "str | None" = None) -> None:
    import jax  # noqa: F401  (device init before timing)

    from repro.api import GraphSession, summarize_outcomes
    from repro.graphstore import generators

    if smoke:
        n_nodes, n_edges, n_labels = 4_000, 24_000, 16
        n_queries, n_shapes = 24, 3
    else:
        n_nodes, n_edges, n_labels = 30_000, 180_000, 24
        n_queries, n_shapes = 64, 4
    first_k = 64
    max_inflight = 8
    block_rows = 256
    deadline_s = 90.0 if smoke else 180.0

    g = generators.rmat(n_nodes, n_edges, n_labels, seed=7, symmetrize=True)
    session = GraphSession.open(g, backend="local")
    rng = np.random.default_rng(13)
    workload = _build_workload(session, g, n_queries, n_shapes, first_k, rng)

    # warm every shape's executables once so both paths measure steady
    # state, not jit compiles (the session cache is shared by both)
    for q, caps in workload[:n_shapes]:
        session.run(q, max_matches=first_k, adaptive=False, **caps)
        for _ in session.stream(q, page_size=first_k, max_matches=first_k,
                                block_rows=block_rows, **caps):
            pass

    # ---- sequential baseline (the pre-server launch/serve.py loop) ------
    seq_lat = []
    t0 = time.perf_counter()
    for q, caps in workload:
        s = time.perf_counter()
        session.run(q, max_matches=first_k, adaptive=False, **caps)
        seq_lat.append(time.perf_counter() - s)
    seq_wall = time.perf_counter() - t0
    seq_qps = len(workload) / seq_wall

    # ---- continuous batching under Poisson open-loop arrivals -----------
    # offered load deliberately exceeds even the server's capacity (the
    # overload case continuous batching exists for), so the in-flight set
    # saturates at max_inflight and measured qps is true throughput; the
    # open loop keeps submitting on exponential gaps regardless of
    # completions, and queue wait counts against each query's deadline
    rate = 128.0 * seq_qps
    gaps = rng.exponential(1.0 / rate, size=len(workload))
    server = session.serve(
        max_inflight=max_inflight,
        block_rows=block_rows,
        max_matches=first_k,
        deadline_s=deadline_s,
    )
    with server:
        t0 = time.perf_counter()
        tickets = []
        for (q, caps), gap in zip(workload, gaps):
            time.sleep(float(gap))
            tickets.append(server.submit(q, **caps))
        outcomes = [t.result(timeout=600) for t in tickets]
        cb_wall = time.perf_counter() - t0
    cb_qps = len(workload) / cb_wall

    ttfp_ms = np.sort([o.ttfp_s * 1e3 for o in outcomes if o.ttfp_s is not None])
    wall_ms = np.sort([o.wall_s * 1e3 for o in outcomes])
    p50, p99 = _percentile(ttfp_ms, 0.50), _percentile(ttfp_ms, 0.99)
    split = summarize_outcomes(outcomes)
    speedup = cb_qps / seq_qps

    print(f"serve_seq_query,{seq_wall/len(workload)*1e6:.1f},"
          f"qps={seq_qps:.2f}")
    print(f"serve_cb_query,{cb_wall/len(workload)*1e6:.1f},"
          f"qps={cb_qps:.2f} speedup={speedup:.2f}x inflight={max_inflight}")
    print(f"serve_cb_ttfp_p50,{p50*1e3:.1f},n={len(ttfp_ms)}")
    print(f"serve_cb_ttfp_p99,{p99*1e3:.1f},"
          f"deadline_ms={deadline_s*1e3:.0f} "
          f"under_deadline={bool(p99 < deadline_s * 1e3)}")
    print(f"serve_cb_outcomes,{server.stats.join_quanta},"
          f"served={split['served']} partial={split['partial']} "
          f"failed={split['failed']} "
          f"global_degradations={server.stats.global_degradations} "
          f"warm_admissions={server.stats.warm_admissions} "
          f"peak_inflight={server.stats.peak_inflight}")

    if hist_out:
        doc = {
            "smoke": smoke,
            "workload": {
                "n_queries": len(workload), "n_shapes": n_shapes,
                "first_k": first_k, "graph_nodes": n_nodes,
                "graph_edges": n_edges,
            },
            "config": {
                "max_inflight": max_inflight, "block_rows": block_rows,
                "deadline_ms": deadline_s * 1e3,
                "offered_qps": rate,
            },
            "sequential": {
                "qps": seq_qps,
                "lat_ms": [t * 1e3 for t in seq_lat],
            },
            "server": {
                "qps": cb_qps,
                "speedup": speedup,
                "ttfp_ms": ttfp_ms.tolist(),
                "wall_ms": wall_ms.tolist(),
                "p50_ttfp_ms": p50,
                "p99_ttfp_ms": p99,
                "outcomes": split,
                "stats": server.stats.as_dict(),
            },
        }
        with open(hist_out, "w") as f:
            json.dump(doc, f, indent=2)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph/workload (the CI serve job)")
    ap.add_argument("--hist-out", type=str, default=None,
                    help="write the latency-histogram JSON here")
    args = ap.parse_args()
    main(smoke=args.smoke, hist_out=args.hist_out)
