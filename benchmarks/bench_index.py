"""Table 1 (STwig row): the only index is the label index — linear size,
linear build time, O(1) update."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.graphstore import PartitionedGraph, generators


def main() -> None:
    sizes = [50_000, 100_000, 200_000, 400_000]
    per_node = []
    for n in sizes:
        g = generators.rmat(n, 4 * n, 64, seed=1)
        t0 = time.perf_counter()
        pg = PartitionedGraph.build(g, 1)
        dt = time.perf_counter() - t0
        idx_bytes = pg.label_indptr.nbytes + pg.nodes_by_label.nbytes
        per_node.append(dt / n)
        emit(
            f"index_build_n{n}",
            dt * 1e6,
            f"bytes={idx_bytes};bytes_per_node={idx_bytes/n:.2f}",
        )
    # linearity: time/node stays ~constant as n grows 8×
    ratio = per_node[-1] / max(per_node[0], 1e-12)
    emit("index_build_linearity", 0.0, f"time_per_node_ratio_8x={ratio:.2f}")


if __name__ == "__main__":
    main()
