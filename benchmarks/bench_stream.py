"""§6.1 pipelined first-K streaming: time-to-first-page and early-stop work
skipped, on both backends.

Reported rows (``name,us_per_call,derived``):

  * ``stream_ttfp_{backend}``      — wall time until the first page of a
    ``stream(page_size=64)`` materializes (us), vs ``run_full_{backend}``,
    the one-shot ``run(max_matches=0)`` time;
  * ``stream_early_skip_{backend}`` — block-join device calls spent by a
    first-page-only consumer; ``derived`` shows ``skipped=X/Y`` — the
    fraction of the full stream's block joins an early stop never ran.

Runs in a subprocess because the sharded half needs multiple XLA host
devices while the bench session keeps one.
"""
from __future__ import annotations

import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import numpy as np
from repro.api import GraphSession
from repro.graphstore import PartitionedGraph, generators
from repro.workloads import path_query

g = generators.rmat(8_000, 48_000, 24, seed=7, symmetrize=True)
rng = np.random.default_rng(11)

for backend, n_shards in (("local", 1), ("sharded", 8)):
    session = GraphSession.open(
        PartitionedGraph.build(g, n_shards), backend=backend
    )
    q = None
    while q is None:
        q = path_query(g, rng, 4)
    # pick caps the query actually fits in: stream vs run comparisons are
    # only valid on complete (non-overflowing) results
    child_cap = 16
    while True:
        cq = session.compile(q, max_matches=0, child_cap=child_cap)
        full = cq.run(adaptive=False)  # also warms every fused executable
        if full.complete or child_cap >= 128:
            break
        child_cap *= 2
    assert full.complete, "query overflows even at child_cap=128"

    t0 = time.perf_counter()
    full = cq.run(adaptive=False)
    run_full = time.perf_counter() - t0

    # ~8 blocks of real work so an early stop has something to skip:
    # provably-empty blocks cost nothing on either backend, so size blocks
    # off the blocked table's VALID row count (head STwig when sharded,
    # smallest table locally; valid rows compact to the front).
    if backend == "sharded":
        blocked = cq.plan.head
    else:
        blocked = min(
            range(len(full.stats.stwig_rows)),
            key=lambda i: full.stats.stwig_rows[i],
        )
    # sharded valid rows split across 8 shards, so divide further to keep
    # several non-empty blocks on the busiest shard
    B = max(1, full.stats.stwig_rows[blocked] // (32 if backend == "sharded" else 8) + 1)

    eng = session.engine
    list(cq.stream(page_size=64, max_matches=0, block_rows=B))  # warm traces

    c0 = eng.join_block_calls
    t0 = time.perf_counter()
    gen = cq.stream(page_size=64, max_matches=0, block_rows=B)
    first = next(gen, None)
    ttfp = time.perf_counter() - t0
    early_calls = eng.join_block_calls - c0
    list(gen)
    full_calls = eng.join_block_calls - c0

    print(f"stream_ttfp_{backend},{ttfp*1e6:.1f},n_first={0 if first is None else first.n_rows}")
    print(f"run_full_{backend},{run_full*1e6:.1f},n_matches={full.n_matches}")
    skipped = full_calls - early_calls
    print(f"stream_early_skip_{backend},{early_calls},skipped={skipped}/{full_calls}")
"""


def main() -> None:
    proc = subprocess.run(
        [sys.executable, "-c", WORKER],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        timeout=3000,
    )
    if proc.returncode != 0:
        print(f"stream_bench_failed,0.0,{proc.stderr[-200:].strip()!r}")
        return
    for line in proc.stdout.strip().splitlines():
        if line.startswith(("stream_", "run_full_")):
            print(line)


if __name__ == "__main__":
    main()
