"""§Perf iteration 3 — load-set collective on the paper's own engine.

Baseline (paper-faithful): masked all-gather — every shard receives every
other shard's STwig table, rows outside the load set masked (with a random
hash partition the cluster graph is complete, so this IS optimal).
Optimized (beyond-paper): distance-bounded ppermute ring on locality-aware
partitions — bytes scale with the load-set radius, not the cluster size.

Measures wall time on 8 simulated machines + analytic bytes-moved.
"""
from __future__ import annotations

import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import numpy as np
from repro.api import GraphSession
from repro.graphstore import PartitionedGraph, generators
from repro.core import QueryGraph

# ring-of-cliques + range partition → sparse (ring) cluster graph
g = generators.ring_of_cliques(n_cliques=8, clique_size=40, n_labels=4, seed=0)
pg = PartitionedGraph.build(g, 8, mode="range")
session = GraphSession.open(pg, backend="sharded")
dm = session.engine
q = QueryGraph.build(labels=[0, 1, 2, 3], edges=[(0, 1), (1, 2), (2, 3), (0, 2)])

compiled = session.compile(q, max_matches=0)
plan = compiled.plan
load = dm.cgi.load_sets(q.label_pairs(), plan.head_dists)
radii = dm.ring_radii_for(load)
print(f"# ring radii per STwig: {radii}")

for use_ring, name in ((False, "allgather"), (True, "ring")):
    r0 = compiled.run(adaptive=False, use_ring=use_ring)  # warmup
    t0 = time.perf_counter()
    for _ in range(3):
        res = compiled.run(adaptive=False, use_ring=use_ring)
    dt = (time.perf_counter() - t0) / 3
    # analytic bytes/shard: allgather = (S-1)*rows; ring = 2*max_radius*rows
    S = 8
    tbl_bytes = sum(
        r * 4 * (w + 1)
        for r, w in [(plan.specs[i].rows_cap, plan.specs[i].width)
                     for i in range(len(plan.specs)) if i != plan.head]
    )
    if use_ring and radii is not None:
        moved = sum(2 * radii[i] * plan.specs[i].rows_cap * 4 * (plan.specs[i].width + 1)
                    for i in range(len(plan.specs)) if i != plan.head)
    else:
        moved = (S - 1) * tbl_bytes
    print(f"loadset_{name},{dt*1e6:.1f},matches={res.n_matches};bytes_per_shard={moved}")
"""


def main() -> None:
    proc = subprocess.run(
        [sys.executable, "-c", WORKER],
        capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        timeout=2000,
    )
    if proc.returncode != 0:
        print(f"loadset_bench_failed,0.0,{proc.stderr[-200:].strip()!r}")
        return
    for line in proc.stdout.strip().splitlines():
        if line.startswith(("loadset_", "#")):
            print(line)


if __name__ == "__main__":
    main()
