"""Fig 8(a,b,c): query time vs query size (DFS + random) and vs edge count.
Patents-like R-MAT (scaled); pipeline termination at 1024 matches (§6.1)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    avg_query_time,
    build_matcher,
    dfs_query,
    emit,
    patents_like,
    random_query,
)


def main(scale: float = 0.008, n_queries: int = 3) -> None:
    g = patents_like(scale, seed=2)
    m = build_matcher(g)
    rng = np.random.default_rng(0)

    # Fig 8(a): DFS queries, node count 3..8
    for nq in range(3, 9):
        qs = [q for q in (dfs_query(g, rng, nq) for _ in range(n_queries)) if q]
        if not qs:
            continue
        t, cnt = avg_query_time(m, qs)
        emit(f"dfs_query_n{nq}", t * 1e6, f"avg_matches={cnt:.0f}")

    import jax
    jax.clear_caches()
    # Fig 8(b): random queries, node count 5..10, E = 2N
    for nq in range(5, 11):
        qs = [random_query(nq, 2 * nq, g.n_labels, rng) for _ in range(n_queries)]
        t, cnt = avg_query_time(m, qs)
        emit(f"random_query_n{nq}", t * 1e6, f"avg_matches={cnt:.0f}")

    jax.clear_caches()
    # Fig 8(c): edge count 10..20 at N=10
    for ne in range(10, 21, 2):
        qs = [random_query(10, ne, g.n_labels, rng) for _ in range(n_queries)]
        t, cnt = avg_query_time(m, qs)
        emit(f"random_query_e{ne}", t * 1e6, f"avg_matches={cnt:.0f}")


if __name__ == "__main__":
    main()
