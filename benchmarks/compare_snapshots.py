"""Diff a fresh bench snapshot against a committed baseline and fail on
regressions.

``PYTHONPATH=src python -m benchmarks.compare_snapshots FRESH [BASELINE]
--suite kernels --max-ratio 1.5``

Compares ``us_per_call`` row by row (matched on ``(suite, name)``) for the
selected suites and exits non-zero if any row regressed by more than
``max-ratio``. BASELINE defaults to the lexically newest committed
``benchmarks/snapshots/BENCH_*.json`` — snapshot files are date-stamped, so
lexical order is chronological order.

Machines differ (the committed baseline may come from faster or slower
hardware than CI), so the ratio gate is deliberately loose: it catches
"this op got several times slower", not single-digit-percent noise. Rows
present on only one side are reported but never fail the gate (new ops have
no baseline; retired ops have no fresh row).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

SNAPSHOTS = pathlib.Path(__file__).resolve().parent / "snapshots"


def _latest_baseline() -> pathlib.Path:
    files = sorted(SNAPSHOTS.glob("BENCH_*.json"))
    if not files:
        raise SystemExit("no committed BENCH_*.json baseline found")
    return files[-1]


def _rows(path: pathlib.Path, suites: set[str] | None) -> dict:
    doc = json.loads(path.read_text())
    return {
        (r["suite"], r["name"]): float(r["us_per_call"])
        for r in doc.get("rows", [])
        if (suites is None or r["suite"] in suites) and r["us_per_call"] > 0
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", type=pathlib.Path)
    ap.add_argument("baseline", type=pathlib.Path, nargs="?", default=None)
    ap.add_argument("--suite", type=str, default="kernels",
                    help="comma-separated suites to gate (default: kernels)")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    help="fail if fresh/baseline us_per_call exceeds this")
    args = ap.parse_args(argv)

    baseline = args.baseline or _latest_baseline()
    suites = set(args.suite.split(",")) if args.suite else None
    fresh = _rows(args.fresh, suites)
    base = _rows(baseline, suites)

    print(f"# baseline: {baseline}")
    regressions = []
    for key in sorted(set(fresh) | set(base)):
        suite, name = key
        if key not in base:
            print(f"NEW       {suite}/{name}: {fresh[key]:.1f}us (no baseline)")
            continue
        if key not in fresh:
            print(f"RETIRED   {suite}/{name}: baseline {base[key]:.1f}us")
            continue
        ratio = fresh[key] / base[key]
        tag = "REGRESSED" if ratio > args.max_ratio else "ok"
        print(f"{tag:9s} {suite}/{name}: {base[key]:.1f}us -> "
              f"{fresh[key]:.1f}us ({ratio:.2f}x)")
        if ratio > args.max_ratio:
            regressions.append((suite, name, ratio))

    if regressions:
        print(f"# {len(regressions)} row(s) regressed past "
              f"{args.max_ratio:.2f}x", file=sys.stderr)
        return 1
    print("# no regressions past the gate")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
