"""Kernel backend microbenchmarks: jnp vs pallas-interpret, per registry op.

Reported rows (``name,us_per_call,derived``): one row per (op, backend),
``derived`` = ``Mrows_s=X`` — millions of processed rows (ids, edges, or
probe keys) per second. On CPU the interpret numbers mostly measure the
Pallas interpreter, not TPU kernels — the point of the suite is (a) a
regression floor for the jnp reference path and (b) a like-for-like harness
that reports real speedups once a TPU is attached (`kernels="pallas"`).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core.backend import get_kernels, n_words

BACKENDS = ("jnp", "pallas-interpret")


def _block(fn):
    """Call + block_until_ready on every leaf."""

    def run():
        out = fn()
        for leaf in jax.tree_util.tree_leaves(out):
            leaf.block_until_ready()

    return run


def _bench_op(name: str, rows: int, make_call) -> None:
    for backend in BACKENDS:
        kern = get_kernels(backend)
        call = jax.jit(make_call(kern))
        us = timed(_block(call)) * 1e6
        emit(f"kernel_{name}_{backend}", us, f"Mrows_s={rows / us:.2f}")


def main() -> None:
    rng = np.random.default_rng(0)

    # ---- bitset ops -------------------------------------------------------
    W = 4096                      # 128 Ki-bit bitset
    n_bits = W * 32
    words = jnp.asarray(rng.integers(0, 2**32, W, dtype=np.uint32))
    mask = jnp.asarray(rng.random(n_bits) < 0.3)
    ids = jnp.asarray(rng.integers(0, n_bits, 1 << 16), jnp.int32)
    valid = jnp.asarray(rng.random(1 << 16) < 0.8)

    _bench_op("bitset_unpack", W, lambda k: lambda: k.bitset_unpack(words))
    _bench_op("bitset_pack", n_bits, lambda k: lambda: k.bitset_pack(mask))
    _bench_op(
        "bitset_lookup", ids.shape[0], lambda k: lambda: k.bitset_lookup(words, ids)
    )
    _bench_op(
        "bitset_build",
        ids.shape[0],
        lambda k: lambda: k.bitset_build(ids, valid, W),
    )

    # ---- candidate filter / stwig_expand ----------------------------------
    E, cap, n_total, C = 1 << 15, 4096, n_bits - 1, 4
    src = np.sort(rng.integers(0, cap, E)).astype(np.int32)
    # (cap+2,) CSR bounds over the edge arrays; indptr[cap+1] == E
    indptr = jnp.asarray(
        np.searchsorted(src, np.arange(cap + 2)).astype(np.int32)
    )
    dst = jnp.asarray(rng.integers(0, n_total, E), jnp.int32)
    labs = jnp.asarray(rng.integers(0, 8, E), jnp.int32)
    rok = jnp.asarray(rng.random(E) < 0.8)
    words_k = jnp.asarray(rng.integers(0, 2**32, (2, n_words(n_total + 1)), dtype=np.uint32))

    _bench_op(
        "candidate_filter",
        E,
        lambda k: lambda: k.candidate_filter(words, dst, labs, rok, 3),
    )
    _bench_op(
        "stwig_expand",
        E,
        lambda k: lambda: k.stwig_expand(
            words_k,
            dst,
            labs,
            indptr,
            rok,
            child_labels=(3, 5),
            child_bound=(True, False),
            child_cap=C,
            cap=cap,
            n_total=n_total,
        ),
    )

    # ---- hash-join probe --------------------------------------------------
    capA, capB, nk, dup = 1 << 14, 1 << 14, 2, 16
    ka = jnp.asarray(np.sort(rng.integers(0, 1 << 20, capA)).astype(np.uint32))
    akeys = jnp.asarray(rng.integers(0, 1 << 16, (capA, nk)), jnp.int32)
    avalid = jnp.asarray(rng.random(capA) < 0.9)
    kb = jnp.asarray(rng.integers(0, 1 << 20, capB), jnp.uint32)
    bkeys = jnp.asarray(rng.integers(0, 1 << 16, (capB, nk)), jnp.int32)
    bvalid = jnp.asarray(rng.random(capB) < 0.9)

    _bench_op(
        "hash_join_probe",
        capB,
        lambda k: lambda: k.hash_join_probe(
            ka, akeys, avalid, kb, bkeys, bvalid, dup_cap=dup
        ),
    )


if __name__ == "__main__":
    main()
