"""Fig 9: speed-up vs machine count (1→8 simulated machines).

Runs in a subprocess because the worker needs multiple XLA host devices
while the bench session keeps one.
"""
from __future__ import annotations

import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import numpy as np, jax
from jax.sharding import Mesh
from repro.graphstore import PartitionedGraph, generators
from repro.core import QueryGraph, SubgraphMatcher
from repro.core.dist import DistributedMatcher

g = generators.rmat(60_000, 16 * 60_000, 64, seed=7)

def dfs_query(g, rng, nq):
    start = int(rng.integers(g.n_nodes))
    nodes, edges, seen = [start], [], {start}
    stack = [start]
    while stack and len(nodes) < nq:
        v = stack.pop()
        for u in g.neighbors(v):
            u = int(u)
            if u not in seen and len(nodes) < nq:
                seen.add(u); nodes.append(u); edges.append((v, u)); stack.append(u)
    if len(nodes) < 2:
        return None
    remap = {v: i for i, v in enumerate(nodes)}
    return QueryGraph.build([int(g.labels[v]) for v in nodes],
                            [(remap[a], remap[b]) for a, b in edges])

rng = np.random.default_rng(11)
queries = []
while len(queries) < 3:
    q = dfs_query(g, rng, 6)
    if q is not None:
        queries.append(q)

for S in (1, 2, 4, 8):
    pg = PartitionedGraph.build(g, S)
    if S == 1:
        m = SubgraphMatcher(pg)
    else:
        mesh = Mesh(np.array(jax.devices()[:S]), ("data",))
        m = DistributedMatcher(pg, mesh)
    # warmup then measure
    for q in queries:
        m.match(q, max_matches=1024, adaptive=False)
    t0 = time.perf_counter()
    for q in queries:
        m.match(q, max_matches=1024, adaptive=False)
    dt = (time.perf_counter() - t0) / len(queries)
    print(f"speedup_machines_{S},{dt*1e6:.1f},")
"""


def main() -> None:
    proc = subprocess.run(
        [sys.executable, "-c", WORKER],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        timeout=3000,
    )
    if proc.returncode != 0:
        print(f"speedup_bench_failed,0.0,{proc.stderr[-200:].strip()!r}")
        return
    for line in proc.stdout.strip().splitlines():
        if line.startswith("speedup_"):
            print(line)


if __name__ == "__main__":
    main()
