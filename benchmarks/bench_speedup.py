"""Fig 9: speed-up vs machine count (1→8 simulated machines).

Runs in a subprocess because the worker needs multiple XLA host devices
while the bench session keeps one.
"""
from __future__ import annotations

import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import numpy as np
from repro.api import GraphSession
from repro.graphstore import PartitionedGraph, generators
from repro.workloads import dfs_query

g = generators.rmat(60_000, 16 * 60_000, 64, seed=7)

rng = np.random.default_rng(11)
queries = []
while len(queries) < 3:
    q = dfs_query(g, rng, 6)
    if q is not None:
        queries.append(q)

for S in (1, 2, 4, 8):
    pg = PartitionedGraph.build(g, S)
    session = GraphSession.open(pg)  # auto: local for S=1, sharded otherwise
    # warmup then measure
    for q in queries:
        session.run(q, max_matches=1024, adaptive=False)
    t0 = time.perf_counter()
    for q in queries:
        session.run(q, max_matches=1024, adaptive=False)
    dt = (time.perf_counter() - t0) / len(queries)
    print(f"speedup_machines_{S},{dt*1e6:.1f},")
"""


def main() -> None:
    proc = subprocess.run(
        [sys.executable, "-c", WORKER],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        timeout=3000,
    )
    if proc.returncode != 0:
        print(f"speedup_bench_failed,0.0,{proc.stderr[-200:].strip()!r}")
        return
    for line in proc.stdout.strip().splitlines():
        if line.startswith("speedup_"):
            print(line)


if __name__ == "__main__":
    main()
