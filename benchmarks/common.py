"""Shared benchmark machinery.

Real datasets (US Patents, WordNet) are unavailable offline; each benchmark
uses R-MAT graphs with matched node/edge/label counts and notes it. Output
rows follow the harness convention: ``name,us_per_call,derived``.

Query generators live in `repro.workloads` (re-exported here for the bench
scripts); matching goes through the `GraphSession` facade.
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import GraphSession
from repro.graphstore import PartitionedGraph, generators
from repro.workloads import dfs_query, random_query  # noqa: F401  (re-export)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *, repeats: int = 3):
    fn()  # warmup (jit compile)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def patents_like(scale: float = 1.0, seed: int = 0):
    """US-Patents-shaped R-MAT: 3.77M nodes, 16.5M edges, 418 labels
    (scaled down by ``scale`` for CPU budgets)."""
    n = max(int(3_774_768 * scale), 1000)
    m = max(int(16_522_438 * scale), 4000)
    return generators.rmat(n, m, 418, seed=seed)


def build_matcher(g, n_shards: int = 1) -> GraphSession:
    """Open a `GraphSession` over ``g`` (name kept for the bench scripts)."""
    return GraphSession.open(
        PartitionedGraph.build(g, n_shards),
        backend="local" if n_shards == 1 else "sharded",
    )


def avg_query_time(
    session: GraphSession,
    queries,
    *,
    max_matches: int = 1024,
    adaptive: bool = False,
) -> tuple[float, float]:
    """Mean wall-time + mean matches over a query set (pipeline semantics:
    first `max_matches` per query, as in the paper's experiments)."""
    times, counts = [], []
    for q in queries:
        t0 = time.perf_counter()
        res = session.run(q, max_matches=max_matches, adaptive=adaptive)
        times.append(time.perf_counter() - t0)
        counts.append(res.n_matches)
    return float(np.mean(times)), float(np.mean(counts))
