"""Shared benchmark machinery.

Real datasets (US Patents, WordNet) are unavailable offline; each benchmark
uses R-MAT graphs with matched node/edge/label counts and notes it. Output
rows follow the harness convention: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import QueryGraph, SubgraphMatcher
from repro.graphstore import PartitionedGraph, generators


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *, repeats: int = 3):
    fn()  # warmup (jit compile)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def dfs_query(g, rng, n_nodes: int) -> QueryGraph | None:
    start = int(rng.integers(g.n_nodes))
    nodes, edges, seen = [start], [], {start}
    stack = [start]
    while stack and len(nodes) < n_nodes:
        v = stack.pop()
        for u in g.neighbors(v):
            u = int(u)
            if u not in seen and len(nodes) < n_nodes:
                seen.add(u)
                nodes.append(u)
                edges.append((v, u))
                stack.append(u)
    if len(nodes) < 2:
        return None
    remap = {v: i for i, v in enumerate(nodes)}
    return QueryGraph.build(
        [int(g.labels[v]) for v in nodes],
        [(remap[a], remap[b]) for a, b in edges],
    )


def random_query(n_nodes, n_edges, n_labels, rng) -> QueryGraph:
    edges = [(int(rng.integers(i)), i) for i in range(1, n_nodes)]
    seen = {(min(a, b), max(a, b)) for a, b in edges}
    tries = 0
    while len(edges) < n_edges and tries < 10 * n_edges:
        a, b = rng.integers(n_nodes, size=2)
        tries += 1
        key = (min(a, b), max(a, b))
        if a != b and key not in seen:
            seen.add(key)
            edges.append((int(a), int(b)))
    return QueryGraph.build(rng.integers(0, n_labels, n_nodes).astype(int).tolist(), edges)


def patents_like(scale: float = 1.0, seed: int = 0):
    """US-Patents-shaped R-MAT: 3.77M nodes, 16.5M edges, 418 labels
    (scaled down by ``scale`` for CPU budgets)."""
    n = max(int(3_774_768 * scale), 1000)
    m = max(int(16_522_438 * scale), 4000)
    return generators.rmat(n, m, 418, seed=seed)


def build_matcher(g, n_shards: int = 1) -> SubgraphMatcher:
    return SubgraphMatcher(PartitionedGraph.build(g, n_shards))


def avg_query_time(
    m: SubgraphMatcher,
    queries,
    *,
    max_matches: int = 1024,
    adaptive: bool = False,
) -> tuple[float, float]:
    """Mean wall-time + mean matches over a query set (pipeline semantics:
    first `max_matches` per query, as in the paper's experiments)."""
    times, counts = [], []
    for q in queries:
        t0 = time.perf_counter()
        res = m.match(q, max_matches=max_matches, adaptive=adaptive)
        times.append(time.perf_counter() - t0)
        counts.append(res.n_matches)
    return float(np.mean(times)), float(np.mean(counts))
