"""§Roofline: per-entry-point rooflines for the MATCHER engines.

`repro.analysis.roofline.engine_rooflines` drives the staticcheck engine
probe (compile / run / stream / re-stream on a tiny graph) for every
(engine × kernel backend) combination, scores every recorded executable
with the staticcheck cost model, and reports one three-term roofline per
entry point — ``bottleneck`` says what a TPU would saturate first for that
executable's op mix, ``frac`` how much of the bounding term pure compute
accounts for. This replaced the stale LM/GNN dry-run artifact reader that
printed ``roofline_no_artifacts`` on every real run.

Rows: ``roofline_<backend>_<kernels>_<entry>,bound_us,bottleneck=..;...``
(``bound_us`` = the bounding term at TPU-v5e constants — a model, not a
measurement; CPU wall-clock lives in the ``kernels`` suite).

``--json-out PATH`` additionally writes the full per-target roofline dicts
as JSON (CI uploads it as an artifact next to the bench snapshot).
"""
from __future__ import annotations

import argparse
import json
import pathlib


def main(json_out: "str | None" = None) -> None:
    from repro.analysis.roofline import engine_rooflines

    rooflines = engine_rooflines()
    doc = {}
    for target, r in rooflines.items():
        # engine:local:jnp:match -> roofline_local_jnp_match
        name = "roofline_" + "_".join(target.split(":")[1:])
        bound = max(r.t_compute, r.t_memory, r.t_collective)
        print(
            f"{name},{bound*1e6:.3f},"
            f"bottleneck={r.bottleneck};frac={r.roofline_fraction:.3f};"
            f"comp_us={r.t_compute*1e6:.3f};mem_us={r.t_memory*1e6:.3f};"
            f"coll_us={r.t_collective*1e6:.3f};"
            f"mflops={r.flops/1e6:.2f};peak_mb={r.hbm_bytes/1e6:.2f}"
        )
        doc[target] = r.to_dict()
    if json_out:
        path = pathlib.Path(json_out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=2) + "\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-out", type=str, default=None,
                    help="also write per-target roofline dicts as JSON")
    main(json_out=ap.parse_args().json_out)
