"""§Roofline: report the three-term roofline for every dry-run artifact
(single-pod mesh) — produced by ``python -m repro.extras.dryrun --all``."""
from __future__ import annotations

import json
import pathlib

ARTIFACTS = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def main() -> None:
    files = sorted(ARTIFACTS.glob("*__16x16.json"))
    if not files:
        print("roofline_no_artifacts,0.0,run `python -m repro.extras.dryrun --all`")
        return
    for f in files:
        d = json.loads(f.read_text())
        name = f"roofline_{d['arch']}_{d['shape']}"
        if d["status"] != "ok":
            print(f"{name},0.0,status={d['status']}")
            continue
        r = d["roofline"]
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        print(
            f"{name},{bound*1e6:.1f},"
            f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.3f};"
            f"comp_ms={r['t_compute_s']*1e3:.2f};mem_ms={r['t_memory_s']*1e3:.2f};"
            f"coll_ms={r['t_collective_s']*1e3:.2f};useful={r['useful_flops_ratio']:.2f}"
        )


if __name__ == "__main__":
    main()
