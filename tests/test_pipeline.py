"""GPipe pipeline over the pod axis == sequential layer application.
Runs in a subprocess with 2 host devices (2 pipeline stages)."""
import json
import pathlib
import subprocess
import sys

import pytest

# whole-module: subprocess 2-device pipeline runs
pytestmark = pytest.mark.slow

from repro.launch.pipeline import bubble_fraction

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np, jax, jax.numpy as jnp
from repro.launch.pipeline import gpipe

mesh = jax.make_mesh((2,), ("pod",))
rng = np.random.default_rng(0)
S, M, mb, d = 2, 4, 8, 16
ws = jnp.asarray(rng.normal(size=(S, d, d)), jnp.float32) * 0.3
x = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)

def stage_fn(w, xb):
    return jnp.tanh(xb @ w)

pipe = gpipe(stage_fn, mesh, axis="pod")
y = pipe(ws, x)

# sequential reference
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ ws[s])
err = float(jnp.max(jnp.abs(y - ref)))
print(json.dumps({"err": err}))
"""


@pytest.mark.parametrize("_", [0])
def test_gpipe_matches_sequential(_):
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["err"] < 1e-5, out


def test_bubble_fraction():
    assert bubble_fraction(2, 4) == pytest.approx(1 / 5)
    assert bubble_fraction(1, 8) == 0.0
    # more microbatches amortize the bubble
    assert bubble_fraction(4, 32) < bubble_fraction(4, 4)
