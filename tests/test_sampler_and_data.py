"""Neighbor sampler invariants + data-pipeline determinism (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import LMConfig, RecSysConfig
from repro.data import lm_batch, recsys_batch
from repro.graphstore import generators
from repro.graphstore.sampler import NeighborSampler


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 50),
    f1=st.integers(2, 8),
    f2=st.integers(2, 6),
    nseeds=st.integers(1, 16),
)
def test_sampler_invariants(seed, f1, f2, nseeds):
    g = generators.rmat(300, 1500, 4, seed=seed)
    s = NeighborSampler(g, (f1, f2), seed=seed)
    seeds = np.random.default_rng(seed).choice(g.n_nodes, nseeds, replace=False)
    sub = s.sample(seeds)
    # capacities hold
    assert sub.n_nodes <= sub.node_cap
    assert int(sub.edge_mask.sum()) <= sub.edge_cap
    # every sampled edge exists in the graph (messages flow neighbor→center)
    for i in np.flatnonzero(sub.edge_mask)[:200]:
        u = sub.nodes[sub.edge_src[i]]
        v = sub.nodes[sub.edge_dst[i]]
        assert u in g.neighbors(v)
    # seeds are first and flagged
    assert (sub.nodes[: len(seeds)] == seeds).all()
    assert sub.seed_mask[: len(seeds)].all()
    # fanout bound: edges into each seed ≤ f1 (its own hop) + f2 (a seed can
    # also appear in the hop-1 frontier of a neighboring seed)
    into_seed = {}
    for i in np.flatnonzero(sub.edge_mask):
        d = int(sub.edge_dst[i])
        into_seed[d] = into_seed.get(d, 0) + 1
    for j in range(len(seeds)):
        assert into_seed.get(j, 0) <= f1 + f2


def test_pipeline_determinism():
    lm = LMConfig(
        name="t", n_layers=1, d_model=8, n_heads=1, n_kv_heads=1, d_head=8,
        d_ff=16, vocab_size=64,
    )
    a = lm_batch(lm, 4, 16, seed=3, step=7)["tokens"]
    b = lm_batch(lm, 4, 16, seed=3, step=7)["tokens"]
    c = lm_batch(lm, 4, 16, seed=3, step=8)["tokens"]
    assert (a == b).all() and not (a == c).all()

    rc = RecSysConfig(name="t", n_sparse=4, embed_dim=4, vocab_per_field=50)
    x = recsys_batch(rc, 8, seed=1, step=2)
    y = recsys_batch(rc, 8, seed=1, step=2)
    assert (x["ids"] == y["ids"]).all()
    assert x["bag_mask"][..., 0].all(), "every bag has ≥1 valid id"
