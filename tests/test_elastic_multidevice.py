"""Elastic rescale across real device-count change: a checkpoint written on
an 8-device mesh restores onto a 4-device mesh and training continues to the
same result as an uninterrupted run (subprocess: needs multiple devices)."""
import json
import pathlib
import subprocess
import sys

import pytest

# whole-module: multi-device subprocess end-to-end runs
pytestmark = pytest.mark.slow

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import optim
from repro.checkpoint import Checkpointer
from repro.runtime import elastic_restore

tmp = tempfile.mkdtemp()
target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)), jnp.float32)
cfg = optim.AdamWConfig(lr=0.05, weight_decay=0.0)

def make_step(mesh):
    sh = NamedSharding(mesh, P("d"))
    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(lambda p: jnp.mean((p["w"] - target) ** 2))(params)
        params, state, _ = optim.update(cfg, g, state, params)
        return jax.lax.with_sharding_constraint(params, {"w": sh}), state, loss
    return step

# phase 1: 8-device mesh, 10 steps, checkpoint
mesh8 = jax.make_mesh((8,), ("d",))
params = {"w": jax.device_put(jnp.zeros((8, 64), jnp.float32), NamedSharding(mesh8, P("d")))}
state = optim.init(cfg, params)
step8 = make_step(mesh8)
for _ in range(10):
    params, state, loss = step8(params, state)
ck = Checkpointer(tmp, async_save=False)
ck.save(10, (params, state))

# phase 2: "lost half the pod" — restore onto a 4-device mesh, train 10 more
mesh4 = jax.make_mesh((4,), ("d",), devices=np.array(jax.devices()[:4]))
sh4 = jax.tree.map(lambda _: NamedSharding(mesh4, P()), (params, state))
sh4[0]["w"] = NamedSharding(mesh4, P("d"))
(restored, step_no) = elastic_restore(ck, (params, state), sh4)
params4, state4 = restored
step4 = make_step(mesh4)
for _ in range(10):
    params4, state4, loss4 = step4(params4, state4)

# reference: uninterrupted 20 steps on 8 devices
params_r = {"w": jax.device_put(jnp.zeros((8, 64), jnp.float32), NamedSharding(mesh8, P("d")))}
state_r = optim.init(cfg, params_r)
for _ in range(20):
    params_r, state_r, loss_r = step8(params_r, state_r)

err = float(np.max(np.abs(np.asarray(params4["w"]) - np.asarray(params_r["w"]))))
print(json.dumps({"step": int(step_no), "err": err,
                  "devices_phase2": len(np.asarray(params4["w"]).shape) and 4}))
"""


def test_elastic_rescale_8_to_4():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["step"] == 10
    assert out["err"] < 1e-5, out
