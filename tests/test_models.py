"""Model-level properties beyond the smoke tests: EGNN equivariance, MLA
decode-vs-train equivalence, MoE routing invariants, EmbeddingBag parity,
rolling KV caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import GNNConfig, LMConfig, MLAConfig, MoEConfig, RecSysConfig
from repro.models import gnn, recsys
from repro.models import transformer as tf
from repro.models.moe import route
from repro.models.schema import init_params


def _graph(rng, N=40, E=160, d_in=8, d_edge=4):
    return gnn.GraphBatch(
        node_feat=jnp.asarray(rng.normal(size=(N, d_in)), jnp.float32),
        edge_src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        edge_dst=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        node_mask=jnp.ones((N,), bool),
        edge_mask=jnp.asarray(rng.random(E) < 0.9),
        edge_feat=jnp.asarray(rng.normal(size=(E, d_edge)), jnp.float32),
        node_pos=jnp.asarray(rng.normal(size=(N, 3)), jnp.float32),
        labels=jnp.asarray(rng.integers(0, 5, N), jnp.int32),
    )


def test_egnn_e3_invariance():
    rng = np.random.default_rng(0)
    g = _graph(rng)
    cfg = GNNConfig(name="e", kind="egnn", n_layers=2, d_hidden=16, d_in=8,
                    d_edge=4, n_classes=5)
    params = gnn.init(cfg, jax.random.PRNGKey(0))
    out1 = gnn.forward(cfg, params, g)
    th = 0.83
    R = jnp.asarray(
        [[np.cos(th), -np.sin(th), 0], [np.sin(th), np.cos(th), 0], [0, 0, 1.0]],
        jnp.float32,
    )
    g2 = g._replace(node_pos=g.node_pos @ R.T + jnp.asarray([3.0, -1.0, 2.0]))
    out2 = gnn.forward(cfg, params, g2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=2e-3)


def test_gnn_padded_edges_are_inert():
    rng = np.random.default_rng(1)
    g = _graph(rng)
    cfg = GNNConfig(name="g", kind="gin", n_layers=2, d_hidden=16, d_in=8,
                    n_classes=5)
    params = gnn.init(cfg, jax.random.PRNGKey(0))
    out1 = gnn.forward(cfg, params, g)
    # corrupting masked-out edges must not change anything
    bad = jnp.where(g.edge_mask, g.edge_src, (g.edge_src + 7) % 40)
    out2 = gnn.forward(cfg, params, g._replace(edge_src=bad))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(t=st.integers(1, 64), e=st.sampled_from([4, 8, 16]), k=st.integers(1, 4),
       seed=st.integers(0, 99))
def test_moe_router_invariants(t, e, k, seed):
    k = min(k, e)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, e)), jnp.float32)
    for kind in ("softmax", "sigmoid"):
        cfg = MoEConfig(n_experts=e, top_k=k, d_ff_expert=8, router=kind)
        idx, wts, aux = route(x, w, None, cfg)
        assert idx.shape == (t, k) and wts.shape == (t, k)
        # distinct experts per token, weights normalized
        for row in np.asarray(idx):
            assert len(set(row.tolist())) == k
        np.testing.assert_allclose(np.asarray(wts).sum(-1), 1.0, rtol=1e-4)


def test_router_bias_balancing_moves_load():
    from repro.models.moe import router_bias_update

    idx = jnp.zeros((100, 2), jnp.int32)  # everything routed to expert 0
    bias = jnp.zeros((4,), jnp.float32)
    new = router_bias_update(bias, idx, 4, gamma=0.1)
    assert float(new[0]) < 0 and all(float(new[i]) > 0 for i in range(1, 4))


def test_rolling_window_cache_matches_full():
    """SWA decode with a rolling cache == decode with a full-length cache."""
    cfg = LMConfig(
        name="w", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_head=16,
        d_ff=64, vocab_size=64, sliding_window=4, dtype="float32",
    )
    params = tf.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, 64)
    full = tf.init_cache(cfg, 1, 16, rolling=False)
    roll = tf.init_cache(cfg, 1, 16, rolling=True)
    assert roll.s_cap == 4
    for pos in range(12):
        lf, full = tf.decode_step(cfg, params, full, toks[:, pos : pos + 1], jnp.int32(pos))
        lr, roll = tf.decode_step(cfg, params, roll, toks[:, pos : pos + 1], jnp.int32(pos))
        if pos >= 4:  # once the window is full, histories agree exactly
            np.testing.assert_allclose(
                np.asarray(lf), np.asarray(lr), atol=1e-4, rtol=1e-4
            )


def test_embedding_bag_ragged_matches_fixed():
    cfg = RecSysConfig(name="r", n_sparse=3, embed_dim=8, vocab_per_field=50)
    params = recsys.init(cfg, jax.random.PRNGKey(0))
    tab = params["tables"][0]
    ids = jnp.asarray([1, 2, 3, 4, 9], jnp.int32)
    bags = jnp.asarray([0, 0, 1, 1, 1], jnp.int32)
    ragged = recsys.embedding_bag_ragged(tab, ids, bags, 2, mode="mean")
    fixed_ids = jnp.asarray([[[1, 2, 0]], [[3, 4, 9]]], jnp.int32)
    mask = jnp.asarray([[[1, 1, 0]], [[1, 1, 1]]], bool)
    fixed = recsys.embedding_bag(tab[None], fixed_ids, mask, mode="mean")
    np.testing.assert_allclose(
        np.asarray(ragged), np.asarray(fixed[:, 0]), rtol=1e-6
    )


def test_mla_decode_matches_train_path():
    cfg = LMConfig(
        name="m", n_layers=2, d_model=48, n_heads=3, n_kv_heads=3, d_head=16,
        d_ff=96, vocab_size=64, dtype="float32",
        mla=MLAConfig(q_lora_rank=24, kv_lora_rank=12, d_nope=16, d_rope=8, d_v=16),
    )
    params = tf.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0, 64)
    logits_full, _, _ = tf.forward(cfg, params, toks)
    cache = tf.init_cache(cfg, 2, 10)
    for pos in range(10):
        lg, cache = tf.decode_step(cfg, params, cache, toks[:, pos : pos + 1], jnp.int32(pos))
    ref = logits_full[:, -1]
    rel = float(jnp.max(jnp.abs(lg[:, 0] - ref))) / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 1e-4, rel
