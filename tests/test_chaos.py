"""Seeded chaos suite: under every injected fault (slow shard, dead shard,
truncated fetch, forced overflow) BOTH engines must return a typed partial
result — a correct *subset* of the true rows, ``complete=False`` where
degraded, the right `DegradeReason` — and never hang, crash, or return
wrong rows.

Runs at whatever device count the interpreter has: 1 shard locally (the
conftest mandates a single CPU device for the main session), up to 4 in the
CI chaos job (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
Every test logs the observed ``MatchStats.shard_health`` to a module-level
journal; when ``REPRO_CHAOS_HEALTH_OUT`` is set the journal is dumped as a
JSON artifact at module teardown (the CI job uploads it).
"""
import json
import os
import pathlib

import jax
import numpy as np
import pytest

from helpers import dfs_query, nx_oracle
from repro.api import GraphSession
from repro.graphstore import generators
from repro.runtime import ChaosConfig, ChaosInjector, RetryPolicy

HEALTH_LOG: list[dict] = []


@pytest.fixture(autouse=True, scope="module")
def _health_artifact():
    yield
    out = os.environ.get("REPRO_CHAOS_HEALTH_OUT")
    if out:
        pathlib.Path(out).write_text(json.dumps(HEALTH_LOG, indent=2))


def _log_health(test: str, stats) -> None:
    HEALTH_LOG.append(
        {
            "test": test,
            "n_devices": len(jax.devices()),
            "shard_health": {str(k): v for k, v in stats.shard_health.items()},
            "degrade_reason": stats.degrade_reason,
            "fetch_retries": stats.fetch_retries,
        }
    )


def _shards() -> int:
    return min(len(jax.devices()), 4)


def _setup(seed=3, qseed=5, qsize=3):
    g = generators.rmat(120, 480, 4, seed=seed, symmetrize=True)
    q = dfs_query(g, np.random.default_rng(qseed), qsize)
    assert q is not None
    return g, q, nx_oracle(g, q)


# ------------------------------------------------------------- cache hygiene


def test_chaos_kernels_name_keys_cache():
    g, q, oracle = _setup()
    chaos = ChaosInjector(ChaosConfig(seed=0))
    with GraphSession.open(g, backend="local", chaos=chaos) as s:
        assert s.kernels.name == "chaos(jnp)"
        res = s.run(q)
        assert set(map(tuple, res.rows.tolist())) == oracle
        # the injector saw real trace-time op traffic through the wrapper
        assert chaos.op_calls["stwig_expand"] > 0
    with GraphSession.open(g, backend="local") as s:
        assert s.kernels.name == "jnp"


# ------------------------------------------------------------------ injector


def test_injector_seeded_determinism():
    a = ChaosInjector(ChaosConfig(seed=9, slow_shard=0, slow_delay_s=0.5))
    b = ChaosInjector(ChaosConfig(seed=9, slow_shard=0, slow_delay_s=0.5))
    assert [a.block_delay() for _ in range(5)] == [
        b.block_delay() for _ in range(5)
    ]
    assert a.fetch_delay() == b.fetch_delay()


# ----------------------------------------------------------------- slow path


def test_slow_shard_delays_but_stays_correct():
    # a straggling shard gates the step (SPMD reality) but degrades nothing
    g, q, oracle = _setup()
    chaos = ChaosInjector(
        ChaosConfig(seed=0, slow_shard=0, slow_delay_s=0.001)
    )
    with GraphSession.open(
        g, backend="sharded", n_shards=_shards(), chaos=chaos
    ) as s:
        res = s.run(q)
    assert res.complete
    assert res.stats.degrade_reason is None
    assert set(map(tuple, res.rows.tolist())) == oracle
    assert res.stats.shard_health.get(0) == "slow"
    _log_health("slow_shard", res.stats)


# ----------------------------------------------------------------- dead path


def test_dead_shard_degrades_to_survivors():
    g, q, oracle = _setup()
    chaos = ChaosInjector(ChaosConfig(seed=0, dead_shard=0))  # never heals
    policy = RetryPolicy(fetch_retries=3, fetch_backoff_s=0.0)
    with GraphSession.open(
        g, backend="sharded", n_shards=_shards(), chaos=chaos
    ) as s:
        res = s.run(q, retry_policy=policy)
    assert not res.complete
    assert res.stats.degrade_reason == "shard-fault"
    assert res.stats.shard_health[0] == "dead"
    assert res.stats.fetch_retries == 3  # exhausted the policy's budget
    # partial, never wrong: surviving shards' rows are true matches
    assert set(map(tuple, res.rows.tolist())) <= oracle
    # adaptive retry must NOT have escalated (not a capacity problem)
    assert res.stats.retries == 0
    _log_health("dead_shard", res.stats)


def test_dead_shard_heals_after_retry():
    g, q, oracle = _setup()
    chaos = ChaosInjector(ChaosConfig(seed=0, dead_shard=0, dead_heals_after=1))
    policy = RetryPolicy(fetch_retries=3, fetch_backoff_s=0.0)
    with GraphSession.open(
        g, backend="sharded", n_shards=_shards(), chaos=chaos
    ) as s:
        # caps big enough to succeed first try: an adaptive escalation
        # would re-run the gate after the heal and reset the health label
        res = s.run(
            q, retry_policy=policy, child_cap=32, join_rows_cap=1 << 18
        )
    assert res.complete
    assert res.stats.degrade_reason is None
    assert res.stats.shard_health[0] == "recovered"
    assert res.stats.fetch_retries >= 1
    assert set(map(tuple, res.rows.tolist())) == oracle
    _log_health("dead_shard_heals", res.stats)


# ------------------------------------------------------------ truncated path


def test_truncated_fetch_degrades_to_subset():
    g, q, oracle = _setup()
    chaos = ChaosInjector(
        ChaosConfig(seed=0, truncate_shard=0, truncate_keep_frac=0.25)
    )
    with GraphSession.open(
        g, backend="sharded", n_shards=_shards(), chaos=chaos
    ) as s:
        res = s.run(q)
    assert not res.complete
    assert res.stats.degrade_reason == "shard-fault"
    assert res.stats.shard_health[0] == "truncated"
    assert set(map(tuple, res.rows.tolist())) <= oracle
    _log_health("truncated_fetch", res.stats)


# ------------------------------------------------------- forced overflow path


@pytest.mark.parametrize("backend", ["local", "sharded"])
def test_forced_overflow_hits_ceiling_with_subset(backend):
    g, q, oracle = _setup()
    chaos = ChaosInjector(ChaosConfig(seed=0, force_overflow=True))
    kw = {"n_shards": _shards()} if backend == "sharded" else {}
    with GraphSession.open(g, backend=backend, chaos=chaos, **kw) as s:
        # ceiling below any escalation: the first overflow is final. Caps
        # big enough that the ONLY overflow is the forced one, so the rows
        # themselves are exact and the flag alone degrades the result.
        res = s.run(
            q,
            retry_policy=RetryPolicy(ceiling_bytes=1.0),
            child_cap=32,
            join_rows_cap=1 << 18,
        )
    assert not res.complete
    assert res.stats.degrade_reason == "overflow-ceiling"
    assert res.stats.retries == 0
    # forced overflow flags capacity, it does not corrupt rows
    assert set(map(tuple, res.rows.tolist())) == oracle
    if backend == "sharded":
        _log_health("forced_overflow", res.stats)


def test_forced_overflow_exhausts_retry_budget():
    g, q, oracle = _setup()
    chaos = ChaosInjector(ChaosConfig(seed=0, force_overflow=True))
    with GraphSession.open(g, backend="local", chaos=chaos) as s:
        res = s.run(
            q,
            retry_policy=RetryPolicy(max_retries=1, ceiling_bytes=float("inf")),
            child_cap=32,
            join_rows_cap=1 << 18,
        )
    assert not res.complete
    assert res.stats.degrade_reason == "overflow-ceiling"
    assert res.stats.retries == 1  # escalated once, still "overflowing"
    assert set(map(tuple, res.rows.tolist())) == oracle


# ----------------------------------------------------- mid-flight abandonment


def test_stream_abandon_leaves_blocks_unjoined_and_cache_sane():
    # satellite: abandoning stream() mid-flight under an injected shard
    # delay must leave the remaining block joins unexecuted and the
    # session's executable cache uncorrupted for the next query
    g, q, oracle = _setup(qseed=2)
    chaos = ChaosInjector(
        ChaosConfig(seed=0, slow_shard=0, slow_delay_s=0.001)
    )
    with GraphSession.open(
        g, backend="sharded", n_shards=_shards(), chaos=chaos
    ) as s:
        # caps big enough that a fully consumed stream is exact (streaming
        # never escalates; equality below needs a complete exploration)
        cq = s.compile(q, child_cap=32, join_rows_cap=1 << 18)
        # reference: a fully consumed stream of the same shape
        full_pages = list(cq.stream(page_size=1, block_rows=4))
        full_calls = s.engine.join_block_calls
        assert sum(p.rows.shape[0] for p in full_pages) == len(oracle)
        assert full_calls >= 2, "need a multi-block stream for this test"

        stream = cq.stream(page_size=1, block_rows=4)
        first = next(stream)
        abandoned_calls = s.engine.join_block_calls - full_calls
        stream.close()  # abandon mid-flight
        assert set(map(tuple, first.rows.tolist())) <= oracle
        assert abandoned_calls < full_calls

        # the session (and its executable cache) is unharmed: the same
        # compiled query and a fresh run() both still answer exactly
        res = cq.run()
        assert res.complete
        assert set(map(tuple, res.rows.tolist())) == oracle
        hits0 = s.cache.hits
        res2 = cq.run()
        assert set(map(tuple, res2.rows.tolist())) == oracle
        assert s.cache.hits > hits0  # reran entirely from cached executables
        _log_health("stream_abandon", res2.stats)


# ------------------------------------------------------------ deadline bound


def test_deadline_bounded_stream_returns_within_2x():
    # acceptance: a deadline-bounded query returns within 2x its deadline.
    # Executables are prewarmed (cache hit on rerun) so the measured wall
    # time is the block loop itself; the injected slow shard makes every
    # block cost ~5ms, the guard trips at the first block past the line.
    import time

    g, q, oracle = _setup(qseed=2)
    chaos = ChaosInjector(ChaosConfig(seed=0, slow_shard=0, slow_delay_s=0.005))
    with GraphSession.open(
        g, backend="sharded", n_shards=_shards(), chaos=chaos
    ) as s:
        cq = s.compile(q)
        list(cq.stream(page_size=1, block_rows=4))  # prewarm every block fn
        deadline = 0.25
        t0 = time.perf_counter()
        pages = list(
            cq.stream(page_size=1, block_rows=4, deadline_s=deadline)
        )
        elapsed = time.perf_counter() - t0
    assert elapsed < 2 * deadline
    got = [r for p in pages for r in map(tuple, p.rows.tolist())]
    assert set(got) <= oracle
