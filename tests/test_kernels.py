"""Per-kernel validation (interpret mode) against the pure-jnp oracles,
with hypothesis shape/dtype sweeps as the brief requires."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

# ------------------------------------------------------------------ flash
from repro.extras.flash_attention import flash_attention, mha_reference


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    s_pow=st.integers(6, 8),
    nkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    h=st.sampled_from([32, 64, 128]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    window=st.sampled_from([None, 32, 100]),
    softcap=st.sampled_from([None, 30.0]),
)
def test_flash_attention_sweep(b, s_pow, nkv, g, h, dtype, window, softcap):
    s = 2**s_pow
    rng = np.random.default_rng(s_pow * 31 + nkv)
    q = jnp.asarray(rng.normal(size=(b, s, nkv * g, h)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, nkv, h)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, nkv, h)), dtype)
    out = flash_attention(q, k, v, window=window, softcap=softcap, interpret=True)
    ref = mha_reference(q, k, v, window=window, softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ----------------------------------------------------------------- bitset
from repro.kernels.bitset import bitset_lookup, bitset_pack, bitset_unpack
from repro.kernels.bitset.ref import (
    lookup_reference,
    pack_reference,
    unpack_reference,
)


@settings(max_examples=15, deadline=None)
@given(w_pow=st.integers(4, 12), seed=st.integers(0, 99))
def test_bitset_roundtrip_sweep(w_pow, seed):
    W = 2**w_pow
    rng = np.random.default_rng(seed)
    words = jnp.asarray(rng.integers(0, 2**32, W, dtype=np.uint32))
    bits = bitset_unpack(words, interpret=True)
    assert (bits == unpack_reference(words)).all()
    assert (bitset_pack(bits, interpret=True) == words).all()
    ids = jnp.asarray(rng.integers(0, W * 32, 1024), jnp.int32)
    assert (
        bitset_lookup(words, ids, interpret=True) == lookup_reference(words, ids)
    ).all()


def test_bitset_matches_graphstore_convention():
    from repro.graphstore.labels import bitset_test_np, pack_bitset

    rng = np.random.default_rng(0)
    mask = rng.random(4096) < 0.2
    words = pack_bitset(mask)
    ids = np.arange(4096)
    got = bitset_lookup(jnp.asarray(words), jnp.asarray(ids, jnp.int32), interpret=True)
    assert (np.asarray(got) == bitset_test_np(words, ids)).all()


# ------------------------------------------------------------- join probe
from repro.extras.join_probe import probe_lower_bound, probe_window
from repro.extras.join_probe.ref import lower_bound_reference, window_reference


@settings(max_examples=15, deadline=None)
@given(
    na=st.integers(16, 2000),
    nb_pow=st.integers(5, 11),
    dup=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 99),
)
def test_join_probe_sweep(na, nb_pow, dup, seed):
    rng = np.random.default_rng(seed)
    # duplicated keys stress the run windows
    ka = np.sort(rng.integers(0, max(na // 4, 2), na)).astype(np.uint32)
    kb = rng.integers(0, max(na // 4, 2), 2**nb_pow).astype(np.uint32)
    lo = probe_lower_bound(jnp.asarray(ka), jnp.asarray(kb), interpret=True)
    assert (np.asarray(lo) == np.asarray(lower_bound_reference(jnp.asarray(ka), jnp.asarray(kb)))).all()
    hit, idx = probe_window(jnp.asarray(ka), jnp.asarray(kb), lo, dup_cap=dup, interpret=True)
    h2, i2 = window_reference(jnp.asarray(ka), jnp.asarray(kb), lo, dup_cap=dup)
    assert (np.asarray(hit) == np.asarray(h2)).all()
    assert (np.asarray(idx) == np.asarray(i2)).all()


# ------------------------------------------------------------- segment_mp
from repro.extras.segment_mp import segment_mp
from repro.extras.segment_mp.ref import segment_mp_reference


@settings(max_examples=12, deadline=None)
@given(
    e_pow=st.integers(6, 11),
    d=st.sampled_from([8, 32, 128]),
    n=st.integers(10, 500),
    seed=st.integers(0, 99),
)
def test_segment_mp_sweep(e_pow, d, n, seed):
    E = 2**e_pow
    rng = np.random.default_rng(seed)
    dst = jnp.asarray(np.sort(rng.integers(0, n, E)), jnp.int32)
    msg = jnp.asarray(rng.normal(size=(E, d)), jnp.float32)
    out = segment_mp(msg, dst, n, interpret=True)
    ref = segment_mp_reference(msg, dst, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


# -------------------------------------------------------------------- cin
from repro.kernels.cin import cin_layer
from repro.kernels.cin.ref import cin_layer_reference


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.sampled_from([4, 20]),
    m=st.sampled_from([6, 10]),
    d=st.sampled_from([64, 128, 256]),
    hp=st.sampled_from([8, 16]),
    seed=st.integers(0, 99),
)
def test_cin_sweep(b, h, m, d, hp, seed):
    rng = np.random.default_rng(seed)
    xk = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    x0 = jnp.asarray(rng.normal(size=(b, m, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(h * m, hp)), jnp.float32)
    out = cin_layer(xk, x0, w, interpret=True)
    ref = cin_layer_reference(xk, x0, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3, rtol=1e-3)


# -------------------------------------------------------- candidate filter
from repro.kernels.bitset import candidate_filter
from repro.kernels.bitset.ref import candidate_filter_reference


@settings(max_examples=10, deadline=None)
@given(e_pow=st.integers(5, 11), nlab=st.integers(2, 6), seed=st.integers(0, 99))
def test_candidate_filter_sweep(e_pow, nlab, seed):
    E = 2**e_pow
    rng = np.random.default_rng(seed)
    W = 256
    words = jnp.asarray(rng.integers(0, 2**32, W, dtype=np.uint32))
    ids = jnp.asarray(rng.integers(0, W * 32, E), jnp.int32)
    labs = jnp.asarray(rng.integers(0, nlab, E), jnp.int32)
    rok = jnp.asarray(rng.random(E) < 0.7)
    got = candidate_filter(words, ids, labs, rok, 1, interpret=True)
    want = candidate_filter_reference(words, ids, labs, rok, 1)
    assert (np.asarray(got) == np.asarray(want)).all()
