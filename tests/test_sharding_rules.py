"""Logical-axis rules: divisibility-aware mappings + spec_for dedup."""
import jax
import pytest

from jax.sharding import PartitionSpec as P

from repro.configs import get
from repro.launch.rules import make_rules
from repro.launch.sharding import axis_rules, spec_for


@pytest.fixture()
def mesh():
    # a 1-device mesh with the production axis NAMES (sizes don't matter for
    # spec construction; divisibility checks use a fake shape below)
    return jax.make_mesh((1, 1, 1), ("pod", "data", "model"))


class _FakeMesh:
    """Stand-in with production axis sizes for rule construction."""

    shape = {"pod": 2, "data": 16, "model": 16}


def test_gemma_heads_cannot_shard():
    rules = make_rules(get("gemma-2b").config, "train", _FakeMesh())
    assert rules["heads"] is None          # 8 heads < 16-way model axis
    assert rules["mlp"] == "model"         # 16384 % 16 == 0
    assert rules["vocab"] == "model"       # 256000 % 16 == 0


def test_qwen_heads_shard():
    rules = make_rules(get("qwen2-72b").config, "train", _FakeMesh())
    assert rules["heads"] == "model"
    assert rules["kv_heads"] is None       # 8 kv heads: replicate


def test_mixtral_experts_fall_back_to_mlp_sharding():
    rules = make_rules(get("mixtral-8x22b").config, "train", _FakeMesh())
    assert rules["expert"] is None         # 8 experts < 16
    assert rules["expert_mlp"] == "model"  # shard the expert FFN dim instead


def test_deepseek_experts_shard():
    rules = make_rules(get("deepseek-v3-671b").config, "train", _FakeMesh())
    assert rules["expert"] == "model"      # 256 % 16 == 0
    assert rules["expert_mlp"] is None


def test_decode_rules_shard_kv_seq():
    cfg = get("qwen2-72b").config
    assert make_rules(cfg, "decode", _FakeMesh())["kv_seq"] == "model"
    long = make_rules(cfg, "decode_long", _FakeMesh())
    assert long["kv_seq"] == ("pod", "data", "model")
    assert long["batch"] is None


def test_spec_for_deduplicates_axes(mesh):
    rules = {"a": ("pod", "data"), "b": "data", "c": None}
    with axis_rules(mesh, rules):
        # "data" already used by the first dim → dropped from the second
        assert spec_for(("a", "b")) == P(("pod", "data"))
        assert spec_for(("b", "a")) == P("data", "pod")
        assert spec_for(("c", None)) == P()
