"""Optimizer substrate: AdamW convergence, int8 moments, schedules, and the
error-feedback gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.optim.compression import EFState, compressed_psum, ef_init


def _optimize(quantize, steps=300):
    cfg = optim.AdamWConfig(lr=0.05, weight_decay=0.0, quantize_moments=quantize)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)), jnp.float32)
    params = {"w": jnp.zeros((8, 16), jnp.float32)}
    state = optim.init(cfg, params)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(
            lambda p: jnp.mean((p["w"] - target) ** 2)
        )(params)
        params, state, _ = optim.update(cfg, g, state, params)
        return params, state, loss

    for _ in range(steps):
        params, state, loss = step(params, state)
    return float(loss)


def test_adamw_converges():
    assert _optimize(False) < 1e-3


def test_adamw_int8_moments_converge():
    # quantized moments trade precision for 4× state bytes; must still optimize
    assert _optimize(True) < 1e-2


def test_cosine_warmup_shape():
    s = optim.cosine_warmup(jnp.arange(1000), warmup=100, total=1000, floor=0.1)
    assert float(s[0]) < 0.02
    assert float(jnp.max(s)) <= 1.0
    np.testing.assert_allclose(float(s[99]), 1.0, atol=0.05)
    np.testing.assert_allclose(float(s[-1]), 0.1, atol=0.01)


def test_grad_compression_error_feedback():
    """int8+EF compression: a constant gradient stream must accumulate to the
    true sum despite per-step quantization error (EF property)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32) * 1e-3

    mesh = jax.make_mesh((1,), ("d",))
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    @jax.jit
    def run(g, err):
        def body(g, err):
            out, ef = compressed_psum(g, EFState(err), "d")
            return out, ef.error

        return shard_map(
            body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False,
        )(g, err)

    total = jnp.zeros_like(g)
    err = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        out, err = run(g, err)
        total = total + out
    # without EF, bias ~ n * quantization_step; with EF it stays ~ 1 step
    np.testing.assert_allclose(
        np.asarray(total), np.asarray(g) * n, atol=2 * float(jnp.max(jnp.abs(g))) / 127
    )


def test_quantized_moment_roundtrip_error():
    from repro.optim.adamw import _dequant, _quant

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    err = jnp.max(jnp.abs(_dequant(_quant(x)) - x))
    per_row_max = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert float(err) <= float(jnp.max(per_row_max)) / 127 + 1e-6
