"""Adaptive capacity growth (paper §4.2): plain-doubling escalation and
retry behaviour when the caller supplies an explicit plan."""
import numpy as np
import pytest

from repro.api import GraphSession
from repro.core import QueryGraph
from repro.core.engine import SubgraphMatcher, caps_from_plan, grow_caps
from repro.graphstore import PartitionedGraph, generators

from helpers import dfs_query, nx_oracle


def test_grow_caps_is_plain_doubling():
    """Pin the escalation sequence: every cap doubles per retry (2**r × the
    seed), never the old super-exponential ``2 * cap * retries`` blow-up."""
    caps = {"child_cap": 8, "join_rows_cap": 1 << 16, "join_dup_cap": 64}
    seq = []
    for _ in range(4):
        caps = grow_caps(caps)
        seq.append(
            (caps["child_cap"], caps["join_rows_cap"], caps["join_dup_cap"])
        )
    assert seq == [
        (16, 1 << 17, 128),
        (32, 1 << 18, 256),
        (64, 1 << 19, 512),
        (128, 1 << 20, 1024),
    ]


def test_grow_caps_defaults_and_passthrough():
    grown = grow_caps({})
    assert grown == {
        "child_cap": 16,
        "join_rows_cap": 1 << 17,
        "join_dup_cap": 128,
    }
    # unrelated keys survive untouched
    grown = grow_caps({"max_matches": 7, "child_cap": 2})
    assert grown["max_matches"] == 7 and grown["child_cap"] == 4


@pytest.fixture(scope="module")
def small_world():
    g = generators.rmat(150, 500, 4, seed=7, symmetrize=True)
    rng = np.random.default_rng(0)
    q = None
    while q is None:
        q = dfs_query(g, rng, 4)
    return g, q


def test_caps_from_plan_recovers_plan_capacities(small_world):
    g, q = small_world
    pg = PartitionedGraph.build(g, 1)
    m = SubgraphMatcher(pg)
    plan = m.plan(q, child_cap=5, join_rows_cap=4096, join_dup_cap=32)
    caps = caps_from_plan(plan)
    assert caps["child_cap"] == 5
    assert caps["join_rows_cap"] == 4096
    assert caps["join_dup_cap"] == 32
    assert caps["max_matches"] == plan.max_matches
    # explicit base kwargs win over plan-derived values
    caps = caps_from_plan(plan, {"child_cap": 11})
    assert caps["child_cap"] == 11


def test_match_escalates_from_explicit_plan(small_world):
    """`SubgraphMatcher.match` used to silently disable adaptive retry when
    a plan was passed; now it escalates from the given plan's caps."""
    g, q = small_world
    pg = PartitionedGraph.build(g, 1)
    m = SubgraphMatcher(pg)
    plan = m.plan(q, child_cap=2, max_matches=0)  # forces an overflow
    res = m.match(q, plan)
    assert res.stats.retries >= 1
    assert res.complete
    assert set(map(tuple, res.rows.tolist())) == nx_oracle(g, q)


def test_compiled_run_and_engine_match_agree_on_escalation(small_world):
    g, q = small_world
    s = GraphSession.open(g)
    facade = s.compile(q, max_matches=0, child_cap=2).run(adaptive=True)
    m = SubgraphMatcher(PartitionedGraph.build(g, 1))
    direct = m.match(q, m.plan(q, child_cap=2, max_matches=0))
    assert facade.complete and direct.complete
    assert set(map(tuple, facade.rows.tolist())) == set(
        map(tuple, direct.rows.tolist())
    )
