"""Single-shard engine vs the networkx oracle (exact result sets)."""
import numpy as np
import pytest

from repro.core import QueryGraph, SubgraphMatcher
from repro.graphstore import PartitionedGraph, generators

from helpers import dfs_query, nx_oracle, random_query


@pytest.fixture(scope="module")
def small_graph():
    g = generators.rmat(120, 420, 4, seed=7, symmetrize=True)
    return g, SubgraphMatcher(PartitionedGraph.build(g, 1))


def test_dfs_queries_exact(small_graph):
    g, m = small_graph
    rng = np.random.default_rng(0)
    checked = 0
    for _ in range(6):
        q = dfs_query(g, rng, 4)
        if q is None:
            continue
        res = m.match(q, max_matches=0)
        assert res.complete
        assert set(map(tuple, res.rows.tolist())) == nx_oracle(g, q)
        checked += 1
    assert checked >= 3


def test_random_queries_exact(small_graph):
    g, m = small_graph
    rng = np.random.default_rng(1)
    for _ in range(3):
        q = random_query(4, 5, 4, rng)
        res = m.match(q, max_matches=0)
        assert res.complete
        assert set(map(tuple, res.rows.tolist())) == nx_oracle(g, q)


def test_duplicate_label_query(small_graph):
    """Queries with repeated labels exercise the injectivity filters."""
    g, m = small_graph
    # triangle-ish query with two nodes sharing a label
    q = QueryGraph.build([0, 0, 1], [(0, 1), (0, 2), (1, 2)])
    res = m.match(q, max_matches=0)
    assert res.complete
    got = set(map(tuple, res.rows.tolist()))
    assert got == nx_oracle(g, q)
    for row in got:
        assert len(set(row)) == len(row), "isomorphism requires distinct nodes"


def test_max_matches_truncation(small_graph):
    g, m = small_graph
    rng = np.random.default_rng(3)
    q = dfs_query(g, rng, 3)
    full = m.match(q, max_matches=0)
    trunc = m.match(q, max_matches=5)
    assert trunc.n_matches <= 5
    assert set(map(tuple, trunc.rows.tolist())) <= set(
        map(tuple, full.rows.tolist())
    )


def test_adaptive_retry_reports(small_graph):
    g, m = small_graph
    rng = np.random.default_rng(4)
    q = None
    while q is None:
        q = dfs_query(g, rng, 4)
    res = m.match(q, max_matches=0, child_cap=2)  # force initial overflow
    assert res.complete  # adaptive retries must recover completeness
    assert set(map(tuple, res.rows.tolist())) == nx_oracle(g, q)


def test_no_matches():
    g = generators.grid_2d(5, 5, 2, seed=0)
    m = SubgraphMatcher(PartitionedGraph.build(g, 1))
    # a 4-clique query cannot embed in a grid
    q = QueryGraph.build([0, 0, 0, 0], [(a, b) for a in range(4) for b in range(a + 1, 4)])
    res = m.match(q, max_matches=0)
    assert res.complete and res.n_matches == 0
