"""Shared test helpers: the networkx brute-force oracle + query generators."""
from __future__ import annotations

import networkx as nx
import numpy as np

from repro.core import QueryGraph
from repro.graphstore.csr import Graph


def nx_oracle(g: Graph, q: QueryGraph) -> set[tuple[int, ...]]:
    """All label-preserving injective embeddings (subgraph monomorphisms) of
    q into g, as tuples indexed by query node id."""
    G = nx.Graph()
    for v in range(g.n_nodes):
        G.add_node(v, label=int(g.labels[v]))
    for v in range(g.n_nodes):
        for u in g.neighbors(v):
            G.add_edge(v, int(u))
    Q = nx.Graph()
    for v in range(q.n_nodes):
        Q.add_node(v, label=q.labels[v])
    Q.add_edges_from(q.edges)
    gm = nx.algorithms.isomorphism.GraphMatcher(
        G, Q, node_match=lambda a, b: a["label"] == b["label"]
    )
    out = set()
    for m in gm.subgraph_monomorphisms_iter():
        inv = {qn: dn for dn, qn in m.items()}
        out.add(tuple(inv[i] for i in range(q.n_nodes)))
    return out


def dfs_query(g: Graph, rng: np.random.Generator, n_nodes: int) -> QueryGraph | None:
    """Paper §6.1 DFS query: traverse from a random node, keep first N."""
    start = int(rng.integers(g.n_nodes))
    nodes, edges, seen = [start], [], {start}
    stack = [start]
    while stack and len(nodes) < n_nodes:
        v = stack.pop()
        for u in g.neighbors(v):
            u = int(u)
            if u not in seen and len(nodes) < n_nodes:
                seen.add(u)
                nodes.append(u)
                edges.append((v, u))
                stack.append(u)
    if len(nodes) < 2:
        return None
    remap = {v: i for i, v in enumerate(nodes)}
    return QueryGraph.build(
        [int(g.labels[v]) for v in nodes],
        [(remap[a], remap[b]) for a, b in edges],
    )


def random_query(
    n_nodes: int, n_edges: int, n_labels: int, rng: np.random.Generator
) -> QueryGraph:
    """Paper §6.1 random query: spanning tree + random extra edges."""
    edges = [(int(rng.integers(i)), i) for i in range(1, n_nodes)]
    tries = 0
    while len(edges) < n_edges and tries < 10 * n_edges:
        a, b = rng.integers(n_nodes, size=2)
        tries += 1
        if a != b and (min(a, b), max(a, b)) not in {
            (min(x, y), max(x, y)) for x, y in edges
        }:
            edges.append((int(a), int(b)))
    labels = rng.integers(0, n_labels, n_nodes).astype(int).tolist()
    return QueryGraph.build(labels, edges)
