"""Shared test helpers: the networkx brute-force oracle. Query generators
come from `repro.workloads` (re-exported so tests keep one import site)."""
from __future__ import annotations

import networkx as nx

from repro.core import QueryGraph
from repro.graphstore.csr import Graph
from repro.workloads import (  # noqa: F401  (re-export)
    dfs_query,
    path_query,
    random_query,
)


def nx_oracle(g: Graph, q: QueryGraph) -> set[tuple[int, ...]]:
    """All label-preserving injective embeddings (subgraph monomorphisms) of
    q into g, as tuples indexed by query node id."""
    G = nx.Graph()
    for v in range(g.n_nodes):
        G.add_node(v, label=int(g.labels[v]))
    for v in range(g.n_nodes):
        for u in g.neighbors(v):
            G.add_edge(v, int(u))
    Q = nx.Graph()
    for v in range(q.n_nodes):
        Q.add_node(v, label=q.labels[v])
    Q.add_edges_from(q.edges)
    gm = nx.algorithms.isomorphism.GraphMatcher(
        G, Q, node_match=lambda a, b: a["label"] == b["label"]
    )
    out = set()
    for m in gm.subgraph_monomorphisms_iter():
        inv = {qn: dn for dn, qn in m.items()}
        out.add(tuple(inv[i] for i in range(q.n_nodes)))
    return out
