"""Sort-merge join vs a brute-force oracle + join-order selection."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.join import JoinTable, Schema, select_join_order, sort_merge_join


def brute_join(rows_a, qn_a, labs_a, rows_b, qn_b, labs_b):
    shared = [q for q in qn_b if q in qn_a]
    pa = [qn_a.index(q) for q in shared]
    pb = [qn_b.index(q) for q in shared]
    merged_q = list(qn_a) + [q for q in qn_b if q not in qn_a]
    merged_l = list(labs_a) + [l for q, l in zip(qn_b, labs_b) if q not in qn_a]
    extra = [i for i, q in enumerate(qn_b) if q not in qn_a]
    out = set()
    for ra in rows_a:
        for rb in rows_b:
            if all(ra[x] == rb[y] for x, y in zip(pa, pb)):
                row = tuple(ra) + tuple(rb[i] for i in extra)
                ok = all(
                    row[i] != row[j]
                    for i in range(len(row))
                    for j in range(i + 1, len(row))
                    if merged_l[i] == merged_l[j]
                )
                if ok:
                    out.add(row)
    return out, tuple(merged_q)


def _table(rows, cap, w=2):
    n = len(rows)
    cols = np.full((cap, w), 10**6, np.int32)
    valid = np.zeros(cap, bool)
    if n:
        cols[:n] = np.asarray(rows, np.int32)
        valid[:n] = True
    return JoinTable(
        cols=jnp.asarray(cols),
        valid=jnp.asarray(valid),
        n_rows=jnp.int32(n),
        overflow=jnp.bool_(False),
    )


@settings(max_examples=25, deadline=None)
@given(
    na=st.integers(0, 40),
    nb=st.integers(0, 40),
    vals=st.integers(3, 12),
    seed=st.integers(0, 999),
)
def test_join_matches_bruteforce(na, nb, vals, seed):
    rng = np.random.default_rng(seed)
    qn_a, labs_a = (0, 1), (0, 1)
    qn_b, labs_b = (1, 2), (1, 0)  # node 2 shares label with node 0
    rows_a = [tuple(rng.integers(0, vals, 2)) for _ in range(na)]
    rows_b = [tuple(rng.integers(0, vals, 2)) for _ in range(nb)]
    ta, tb = _table(rows_a, 64), _table(rows_b, 64)
    out, schema = sort_merge_join(
        ta, tb, Schema(qn_a, labs_a), Schema(qn_b, labs_b), out_cap=4096, dup_cap=64
    )
    got = set(
        map(tuple, np.asarray(out.cols)[np.asarray(out.valid)].tolist())
    )
    want, merged_q = brute_join(rows_a, qn_a, labs_a, rows_b, qn_b, labs_b)
    assert schema.qnodes == merged_q
    assert not bool(out.overflow)
    assert got == want


def test_join_dup_overflow_flag():
    rows_a = [(5, i) for i in range(30)]  # 30 rows share join key 5
    rows_b = [(5, 99)]
    ta, tb = _table(rows_a, 32), _table(rows_b, 8)
    out, _ = sort_merge_join(
        ta, tb, Schema((0, 1), (0, 1)), Schema((0, 2), (0, 2)),
        out_cap=512, dup_cap=8,
    )
    assert bool(out.overflow), "run longer than dup_cap must flag overflow"


def test_select_join_order_connected():
    schemas = [
        Schema((0, 1), (0, 0)),
        Schema((2, 3), (1, 1)),
        Schema((1, 2), (0, 1)),
    ]
    order = select_join_order(schemas, [100, 10, 50])
    # starts from the smallest, and every next table shares a query node
    assert order[0] == 1
    joined = set(schemas[order[0]].qnodes)
    for i in order[1:]:
        assert joined & set(schemas[i].qnodes)
        joined |= set(schemas[i].qnodes)
