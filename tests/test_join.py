"""Sort-merge join vs a brute-force oracle, failure paths (dup_cap overflow,
hash collisions, ≥3 equal-label injectivity) + join-order selection."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # only the property-based sweep needs hypothesis
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core.join import JoinTable, Schema, select_join_order, sort_merge_join


def brute_join(rows_a, qn_a, labs_a, rows_b, qn_b, labs_b):
    shared = [q for q in qn_b if q in qn_a]
    pa = [qn_a.index(q) for q in shared]
    pb = [qn_b.index(q) for q in shared]
    merged_q = list(qn_a) + [q for q in qn_b if q not in qn_a]
    merged_l = list(labs_a) + [l for q, l in zip(qn_b, labs_b) if q not in qn_a]
    extra = [i for i, q in enumerate(qn_b) if q not in qn_a]
    out = set()
    for ra in rows_a:
        for rb in rows_b:
            if all(ra[x] == rb[y] for x, y in zip(pa, pb)):
                row = tuple(ra) + tuple(rb[i] for i in extra)
                ok = all(
                    row[i] != row[j]
                    for i in range(len(row))
                    for j in range(i + 1, len(row))
                    if merged_l[i] == merged_l[j]
                )
                if ok:
                    out.add(row)
    return out, tuple(merged_q)


def _table(rows, cap, w=2):
    n = len(rows)
    cols = np.full((cap, w), 10**6, np.int32)
    valid = np.zeros(cap, bool)
    if n:
        cols[:n] = np.asarray(rows, np.int32)
        valid[:n] = True
    return JoinTable(
        cols=jnp.asarray(cols),
        valid=jnp.asarray(valid),
        n_rows=jnp.int32(n),
        overflow=jnp.bool_(False),
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        na=st.integers(0, 40),
        nb=st.integers(0, 40),
        vals=st.integers(3, 12),
        seed=st.integers(0, 999),
    )
    def test_join_matches_bruteforce(na, nb, vals, seed):
        _join_matches_bruteforce(na, nb, vals, seed)


def test_join_matches_bruteforce_pinned():
    # hypothesis-free spot checks so the oracle comparison always runs
    for na, nb, vals, seed in ((7, 9, 5, 0), (20, 20, 4, 1), (0, 5, 3, 2)):
        _join_matches_bruteforce(na, nb, vals, seed)


def _join_matches_bruteforce(na, nb, vals, seed):
    rng = np.random.default_rng(seed)
    qn_a, labs_a = (0, 1), (0, 1)
    qn_b, labs_b = (1, 2), (1, 0)  # node 2 shares label with node 0
    rows_a = [tuple(rng.integers(0, vals, 2)) for _ in range(na)]
    rows_b = [tuple(rng.integers(0, vals, 2)) for _ in range(nb)]
    ta, tb = _table(rows_a, 64), _table(rows_b, 64)
    out, schema = sort_merge_join(
        ta, tb, Schema(qn_a, labs_a), Schema(qn_b, labs_b), out_cap=4096, dup_cap=64
    )
    got = set(
        map(tuple, np.asarray(out.cols)[np.asarray(out.valid)].tolist())
    )
    want, merged_q = brute_join(rows_a, qn_a, labs_a, rows_b, qn_b, labs_b)
    assert schema.qnodes == merged_q
    assert not bool(out.overflow)
    assert got == want


def test_join_dup_overflow_flag():
    rows_a = [(5, i) for i in range(30)]  # 30 rows share join key 5
    rows_b = [(5, 99)]
    ta, tb = _table(rows_a, 32), _table(rows_b, 8)
    out, _ = sort_merge_join(
        ta, tb, Schema((0, 1), (0, 1)), Schema((0, 2), (0, 2)),
        out_cap=512, dup_cap=8,
    )
    assert bool(out.overflow), "run longer than dup_cap must flag overflow"


def test_join_dup_overflow_boundary():
    """Run length == dup_cap is fine; dup_cap + 1 must flag overflow."""
    rows_b = [(5, 99)]
    for n_dup, want in ((8, False), (9, True)):
        rows_a = [(5, i) for i in range(n_dup)]
        ta, tb = _table(rows_a, 16), _table(rows_b, 8)
        out, _ = sort_merge_join(
            ta, tb, Schema((0, 1), (0, 1)), Schema((0, 2), (0, 2)),
            out_cap=512, dup_cap=8,
        )
        assert bool(out.overflow) is want
        if not want:  # results stay exact up to the cap
            got = np.asarray(out.cols)[np.asarray(out.valid)]
            assert got.shape[0] == n_dup


# colliding 2-column keys through `_mix32`/`_combine_keys`, found by brute
# force over a 4096x4096 grid (see test body for the premise check)
_COLLIDING_A = (810, 3454)
_COLLIDING_B = (1838, 3011)


def test_hash_collision_rejected_by_exact_verification():
    """Two different key tuples with the SAME combined hash must not join:
    the probe window sees a hash hit, exact column verification kills it."""
    from repro.core.join import _combine_keys

    ka = _combine_keys(jnp.asarray([_COLLIDING_A], jnp.int32), (0, 1))
    kb = _combine_keys(jnp.asarray([_COLLIDING_B], jnp.int32), (0, 1))
    assert int(ka[0]) == int(kb[0]), "premise: keys must collide under _mix32"

    schema_a = Schema((0, 1), (0, 1))
    schema_b = Schema((0, 1, 2), (0, 1, 2))
    ta = _table([_COLLIDING_A], 8)
    # colliding (but unequal) probe row + one genuinely matching row
    tb = _table([_COLLIDING_B + (7,), _COLLIDING_A + (9,)], 8, w=3)
    out, schema = sort_merge_join(
        ta, tb, schema_a, schema_b, out_cap=64, dup_cap=4
    )
    got = set(map(tuple, np.asarray(out.cols)[np.asarray(out.valid)].tolist()))
    assert got == {_COLLIDING_A + (9,)}, got
    assert not bool(out.overflow)


def test_injectivity_filter_three_equal_label_columns():
    """With >= 3 equal-label columns the incremental filter must also reject
    NON-adjacent duplicate pairs introduced by the merge."""
    schema_a = Schema((0, 1), (5, 5))
    schema_b = Schema((1, 2), (5, 5))
    ta = _table([(1, 2)], 8)
    # (2, 1) closes a duplicate with column 0 (non-adjacent pair 0/2);
    # (2, 3) is a clean extension
    tb = _table([(2, 1), (2, 3)], 8)
    out, schema = sort_merge_join(
        ta, tb, schema_a, schema_b, out_cap=64, dup_cap=4
    )
    assert schema.qnodes == (0, 1, 2) and schema.qlabels == (5, 5, 5)
    got = set(map(tuple, np.asarray(out.cols)[np.asarray(out.valid)].tolist()))
    assert got == {(1, 2, 3)}, got


def test_select_join_order_connected():
    schemas = [
        Schema((0, 1), (0, 0)),
        Schema((2, 3), (1, 1)),
        Schema((1, 2), (0, 1)),
    ]
    order = select_join_order(schemas, [100, 10, 50])
    # starts from the smallest, and every next table shares a query node
    assert order[0] == 1
    joined = set(schemas[order[0]].qnodes)
    for i in order[1:]:
        assert joined & set(schemas[i].qnodes)
        joined |= set(schemas[i].qnodes)
