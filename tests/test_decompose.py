"""Algorithm 2 (STwig decomposition + ordering): paper walkthrough +
properties (cover, edge-disjointness, Theorem 2 bound)."""
import itertools

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import QueryGraph, f_values, head_stwig_selection, stwig_order_selection


def paper_fig6_query():
    name = {c: i for i, c in enumerate("abcdef")}
    edges = [("d", "b"), ("d", "c"), ("d", "e"), ("d", "f"),
             ("c", "a"), ("c", "f"), ("b", "a"), ("b", "f")]
    q = QueryGraph.build(
        labels=list(range(6)), edges=[(name[a], name[b]) for a, b in edges]
    )
    return q, name


def test_paper_walkthrough_fvalues():
    q, name = paper_fig6_query()
    f = f_values(q, np.full(6, 10))
    assert f[name["d"]] == pytest.approx(0.4)
    assert f[name["c"]] == pytest.approx(0.3)
    assert f[name["a"]] == pytest.approx(0.2)
    assert f[name["e"]] == pytest.approx(0.1)


def test_paper_walkthrough_decomposition():
    q, name = paper_fig6_query()
    dec = stwig_order_selection(q, np.full(6, 10))
    # paper result: 3 STwigs, first rooted at d with children {b, c, e, f};
    # the other two rooted at b and c (order is a documented tie-break)
    assert len(dec.stwigs) == 3
    assert dec.stwigs[0].root == name["d"]
    assert set(dec.stwigs[0].children) == {name[c] for c in "bcef"}
    assert {t.root for t in dec.stwigs} == {name[c] for c in "bcd"}
    assert dec.covers(q) and dec.edge_disjoint()
    # rule 1: every non-first root is bound by earlier STwigs
    for t, bb in list(zip(dec.stwigs, dec.bound_before))[1:]:
        assert t.root in bb


def _min_vertex_cover_size(q: QueryGraph) -> int:
    n = q.n_nodes
    for k in range(n + 1):
        for sub in itertools.combinations(range(n), k):
            s = set(sub)
            if all(u in s or v in s for u, v in q.edges):
                return k
    return n


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_two_approximation_property(data):
    n = data.draw(st.integers(3, 7))
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    edges = [(int(rng.integers(i)), i) for i in range(1, n)]
    extra = data.draw(st.integers(0, n))
    for _ in range(extra):
        a, b = rng.integers(n, size=2)
        if a != b:
            edges.append((int(a), int(b)))
    q = QueryGraph.build(rng.integers(0, 3, n).astype(int).tolist(), edges)
    freq = np.full(3, 10)
    dec = stwig_order_selection(q, freq)
    assert dec.covers(q), "every query edge in exactly one STwig"
    assert dec.edge_disjoint()
    # Theorem 2: |T| <= 2 · |optimal cover| = 2 · |min vertex cover|
    assert len(dec.stwigs) <= 2 * max(_min_vertex_cover_size(q), 1)


def test_head_stwig_minimizes_eccentricity():
    q, name = paper_fig6_query()
    dec = stwig_order_selection(q, np.full(6, 10))
    head, dists = head_stwig_selection(q, dec)
    M = q.shortest_paths()
    roots = [t.root for t in dec.stwigs]
    ecc = [max(M[r, r2] for r2 in roots) for r in roots]
    assert ecc[head] == min(ecc)
    assert dists[head] == 0
