"""Property tests for the collectives at every axis size 1..8.

CI's multi-device jobs only ever run the collectives at S=8 (a power of
two), so the non-power-of-two all-gather fallback in `or_allreduce` and the
ring clamp `h = min(max_dist, (S-1)//2)` in `gather_load_set_ring` were
untested. One 8-forced-device subprocess builds a sub-mesh of every size
S ∈ 1..8 and checks, per size:

  * ``or_allreduce`` equals the host-side OR reduction (butterfly path for
    powers of two, gather fallback otherwise, identity at S=1);
  * ``gather_load_set_ring`` returns exactly the same valid rows as the
    faithful ``gather_load_set`` whenever the load set respects the ring
    radius — including max_dist larger than the reachable radius (the
    clamp) and max_dist=0 (self only).

Multi-device, so subprocess-isolated (the main session keeps one device).
"""
import json
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core.collectives import (
    gather_load_set, gather_load_set_ring, or_allreduce,
)

out = {"or": {}, "ring": {}}
W, CAP, COLS = 16, 6, 3

for S in range(1, 9):
    mesh = Mesh(np.array(jax.devices()[:S]), ("data",))
    rng = np.random.default_rng(100 + S)

    # ---- or_allreduce == host OR-reduce --------------------------------
    words = rng.integers(0, 2**32, (S, W), dtype=np.uint32)
    f = jax.jit(shard_map(
        lambda w: or_allreduce(w[0], "data")[None],
        mesh=mesh, in_specs=(P("data"),), out_specs=P("data"),
        check_vma=False,
    ))
    got = np.asarray(f(words))
    want = np.bitwise_or.reduce(words, axis=0)
    out["or"][S] = bool((got == want[None]).all())

    # ---- ring fetch == all-gather fetch on ring-shaped load sets -------
    cols = rng.integers(0, 1000, (S, CAP, COLS), dtype=np.int32)
    valid = rng.random((S, CAP)) < 0.7
    for max_dist in (0, 1, 2, 5):
        h = min(max_dist, (S - 1) // 2)
        # load sets constrained to the reachable ring distance, random
        # within it (shard i may fetch shard j iff ring_dist(i,j) <= h)
        dist = np.minimum(
            (np.arange(S)[:, None] - np.arange(S)) % S,
            (np.arange(S) - np.arange(S)[:, None]) % S,
        )
        load = (rng.random((S, S)) < 0.8) & (dist <= h)
        np.fill_diagonal(load, True)

        def ring_body(c, v, l):
            gc, gv = gather_load_set_ring(c[0], v[0], l[0], "data", max_dist)
            return gc[None], gv[None]

        def full_body(c, v, l):
            gc, gv = gather_load_set(c[0], v[0], l[0], "data")
            return gc[None], gv[None]

        specs = (P("data"), P("data"), P("data"))
        ring = jax.jit(shard_map(
            ring_body, mesh=mesh, in_specs=specs,
            out_specs=(P("data"), P("data")), check_vma=False,
        ))
        full = jax.jit(shard_map(
            full_body, mesh=mesh, in_specs=specs,
            out_specs=(P("data"), P("data")), check_vma=False,
        ))
        rc, rv = map(np.asarray, ring(cols, valid, load))
        fc, fv = map(np.asarray, full(cols, valid, load))
        ok = True
        for i in range(S):
            ring_rows = sorted(map(tuple, rc[i][rv[i]].tolist()))
            full_rows = sorted(map(tuple, fc[i][fv[i]].tolist()))
            ok &= ring_rows == full_rows
        # capacity contract: (2h+1) * CAP rows after the clamp
        ok &= rc.shape == (S, (2 * h + 1) * CAP, COLS)
        out["ring"][f"{S}:{max_dist}"] = bool(ok)

# ---- cost-model collective bytes == roofline HLO parse ---------------
# (needs a real multi-device mesh: XLA deletes collectives at S=1)
from repro.analysis.staticcheck import costmodel

mesh8 = Mesh(np.array(jax.devices()), ("data",))

def coll_body(v):
    s = jax.lax.psum(v[0], "data")                    # all-reduce
    g = jax.lax.all_gather(v[0], "data", tiled=True)  # all-gather
    p = jax.lax.ppermute(                             # collective-permute
        v[0], "data", perm=[(i, (i + 1) % 8) for i in range(8)]
    )
    return (s + p)[None], g[None]

x = np.arange(8 * 256, dtype=np.float32).reshape(8, 256)
f = shard_map(coll_body, mesh=mesh8, in_specs=(P("data"),),
              out_specs=(P("data"), P("data")), check_vma=False)
xc = costmodel.hlo_cross_check(f, x, n_devices=8)
rel = abs(xc["est_collective_bytes"] - xc["hlo_collective_bytes"]) / max(
    xc["hlo_collective_bytes"], 1.0
)
out["collective_bytes"] = {
    "est": xc["est_collective_bytes"],
    "hlo": xc["hlo_collective_bytes"],
    "rel_err": rel,
}

print(json.dumps(out))
"""


def test_collectives_all_axis_sizes():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env={
            "PYTHONPATH": SRC,
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": "cpu",
        },
    )
    assert proc.returncode == 0, proc.stderr
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    bad_or = [s for s, ok in out["or"].items() if not ok]
    bad_ring = [k for k, ok in out["ring"].items() if not ok]
    assert not bad_or, f"or_allreduce mismatch at axis sizes {bad_or}"
    assert not bad_ring, f"ring fetch mismatch at (S:max_dist) {bad_ring}"
    # acceptance: static collective-bytes estimate vs roofline HLO parse
    cb = out["collective_bytes"]
    assert cb["hlo"] > 0, cb
    assert cb["rel_err"] <= 0.10, cb
