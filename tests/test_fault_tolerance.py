"""Checkpoint/restart, failure injection, elastic resharding, straggler flag."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import Checkpointer
from repro.runtime import SimulatedPreemption, TrainSupervisor, elastic_restore


def _toy_setup(tmp_path, ckpt_every=5):
    cfg = optim.AdamWConfig(lr=0.05, weight_decay=0.0)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.float32)
    params = {"w": jnp.zeros((4, 8), jnp.float32)}
    state = (params, optim.init(cfg, params))

    @jax.jit
    def raw(params, opt_state, batch, step):
        loss, g = jax.value_and_grad(
            lambda p: jnp.mean((p["w"] - batch) ** 2)
        )(params)
        params, opt_state, m = optim.update(cfg, g, opt_state, params)
        return params, opt_state, {"loss": loss, **m}

    def step_fn(state, batch, step):
        p, s = state
        p, s, m = raw(p, s, batch, np.int32(step))
        return (p, s), m

    def batch_fn(step):  # deterministic in step → resumable
        return target + 0.01 * np.float32(step % 3)

    ckpt = Checkpointer(tmp_path, async_save=True)
    return state, step_fn, batch_fn, ckpt


def test_restart_is_lossless(tmp_path):
    state0, step_fn, batch_fn, ckpt = _toy_setup(tmp_path / "a")
    sup = TrainSupervisor(ckpt, ckpt_every=5)
    # uninterrupted reference run
    ref_state, _ = sup.run(
        state=state0, step_fn=step_fn, batch_fn=batch_fn, n_steps=20,
        start_step=0,
    )

    state0b, step_fn, batch_fn, ckpt_b = _toy_setup(tmp_path / "b")
    sup_b = TrainSupervisor(
        ckpt_b, ckpt_every=5,
        fail_at={12: lambda: SimulatedPreemption("node lost")},
    )
    with pytest.raises(SimulatedPreemption):
        sup_b.run(state=state0b, step_fn=step_fn, batch_fn=batch_fn, n_steps=20)
    # restart: resumes from step 10 checkpoint and finishes
    final, hist = sup_b.run(
        state=state0b, step_fn=step_fn, batch_fn=batch_fn, n_steps=20
    )
    assert hist[0]["step"] == 10
    np.testing.assert_allclose(
        np.asarray(final[0]["w"]), np.asarray(ref_state[0]["w"]), rtol=1e-6
    )


def test_elastic_restore_roundtrip(tmp_path):
    state, step_fn, batch_fn, ckpt = _toy_setup(tmp_path)
    sup = TrainSupervisor(ckpt, ckpt_every=5)
    final, _ = sup.run(state=state, step_fn=step_fn, batch_fn=batch_fn, n_steps=10)
    # "new mesh": single-device NamedShardings (the host-gather layout makes
    # any target mesh valid — exercised at 8 devices in test_distributed)
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), final)
    restored, step = elastic_restore(ckpt, final, sh)
    assert step == 10
    np.testing.assert_allclose(
        np.asarray(restored[0]["w"]), np.asarray(final[0]["w"])
    )


def test_async_checkpoint_and_gc(tmp_path):
    state, step_fn, batch_fn, ckpt = _toy_setup(tmp_path)
    sup = TrainSupervisor(ckpt, ckpt_every=2)
    sup.run(state=state, step_fn=step_fn, batch_fn=batch_fn, n_steps=12)
    steps = sorted(p.name for p in (tmp_path).glob("step_*"))
    assert len(steps) <= ckpt.keep
    assert steps[-1] == "step_00000012"


def test_straggler_flagging(tmp_path):
    state, step_fn, batch_fn, ckpt = _toy_setup(tmp_path)
    sup = TrainSupervisor(ckpt, ckpt_every=100, straggler_factor=3.0)

    slow = {"n": 0}

    def slow_step(state, batch, step):
        import time

        slow["n"] += 1
        if step == 15:
            time.sleep(0.5)  # inject a straggler-shaped stall
        return step_fn(state, batch, step)

    _, hist = sup.run(
        state=state, step_fn=slow_step, batch_fn=batch_fn, n_steps=20
    )
    flags = [h["step"] for h in hist if h["straggler_flag"]]
    assert 15 in flags


def test_straggler_detection_is_not_self_dampened():
    """Pinned regression test: the EWMA must be compared BEFORE folding the
    new step in. The old update-then-compare order let a straggling step
    drag the average toward itself: at factor 3 a 3.2x stall over a 0.1s
    baseline went unflagged (threshold effectively ~4.3x)."""
    from repro.runtime import straggler_update

    # seed step: establishes the baseline, never flagged
    ewma, flagged = straggler_update(None, 0.1, 3.0)
    assert ewma == pytest.approx(0.1) and not flagged

    # a 3.2x stall must be flagged ...
    dt = 0.32
    ewma2, flagged = straggler_update(ewma, dt, 3.0)
    assert flagged
    # ... and it IS the case the old order missed: after folding dt in,
    # the dampened threshold exceeds the stall
    dampened = 0.9 * ewma + 0.1 * dt
    assert dt <= 3.0 * dampened
    # the stall still joins the average afterwards (detection, not denial)
    assert ewma2 == pytest.approx(dampened)

    # steady state below the factor stays quiet
    _, flagged = straggler_update(ewma2, 0.12, 3.0)
    assert not flagged
