"""Roofline machinery: HLO collective parser, term arithmetic, flop models."""
import numpy as np

from repro.analysis.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    engine_rooflines,
    model_flops_lm,
    parse_collectives,
)
from repro.configs import get

FAKE_HLO = """
ENTRY %main {
  %ag = bf16[8,1024,128]{2,1,0} all-gather(bf16[1,1024,128]{2,1,0} %p0), dims={0}
  %ar.1 = f32[4096]{0} all-reduce(f32[4096]{0} %p1), to_apply=%add
  %rs = f32[512,16]{1,0} reduce-scatter(f32[4096,16]{1,0} %x), dimensions={0}
  %cp = u32[256]{0} collective-permute(u32[256]{0} %y), source_target_pairs={{0,1}}
  %a2a = bf16[64,64]{1,0} all-to-all(bf16[64,64]{1,0} %z), dimensions={0}
  %ars = f32[128]{0} all-reduce-start(f32[128]{0} %w), to_apply=%add
}
"""


def test_parse_collectives_kinds_and_bytes():
    st = parse_collectives(FAKE_HLO, n_devices=8)
    assert st.count_by_kind == {
        "all-gather": 1,
        "all-reduce": 2,
        "reduce-scatter": 1,
        "collective-permute": 1,
        "all-to-all": 1,
    }
    ring = 7 / 8
    assert np.isclose(
        st.bytes_by_kind["all-gather"], 8 * 1024 * 128 * 2 * ring
    )
    assert np.isclose(
        st.bytes_by_kind["all-reduce"], (4096 * 4 + 128 * 4) * 2 * ring
    )
    assert np.isclose(st.bytes_by_kind["collective-permute"], 256 * 4)


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        flops=PEAK_FLOPS,         # exactly 1 s of compute
        hbm_bytes=HBM_BW / 2,     # 0.5 s
        collective_bytes=ICI_BW * 2,  # 2 s
        n_chips=4,
        model_flops=PEAK_FLOPS * 4,  # ideal = 1 s/chip
    )
    assert np.isclose(r.t_compute, 1.0)
    assert np.isclose(r.t_memory, 0.5)
    assert np.isclose(r.t_collective, 2.0)
    assert r.bottleneck == "collective"
    assert np.isclose(r.useful_flops_ratio, 1.0)
    assert np.isclose(r.roofline_fraction, 0.5)  # ideal 1s / bound 2s


def test_engine_rooflines_attribute_matcher_entry_points():
    """The matcher-targeted roofline: cost-model attribution for every
    recorded engine entry point, no dry-run artifacts involved. One
    (engine x kernels) combination keeps the probe cheap; the benchmark
    suite (bench_roofline) runs all four."""
    rooflines = engine_rooflines(backends=("local",), kernels=("jnp",))
    # the probe query decomposes into >=2 STwigs: match AND join entry
    # points must both be recorded and attributed
    targets = set(rooflines)
    assert any(t.endswith(":match") for t in targets), targets
    assert any(t.endswith(":join") for t in targets), targets
    for target, r in rooflines.items():
        assert target.startswith("engine:local:jnp:")
        assert r.flops > 0 and r.hbm_bytes > 0
        assert r.bottleneck in ("compute", "memory", "collective")
        assert 0.0 < r.roofline_fraction <= 1.0
        d = r.to_dict()
        assert d["bottleneck"] == r.bottleneck
    # single-process probe moves no collective bytes -> never the bottleneck
    assert all(r.bottleneck != "collective" for r in rooflines.values())


def test_model_flops_published_configs():
    # 6·N_active·D sanity for DeepSeek-V3: 37B active × 6 × tokens
    cfg = get("deepseek-v3-671b").config
    f = model_flops_lm(cfg, batch=256, seq=4096, kind="train")
    tokens = 256 * 4096
    assert np.isclose(f, 6 * cfg.n_active_params() * tokens)
    assert 35e9 < cfg.n_active_params() < 40e9
    # decode counts one token per sequence
    f_dec = model_flops_lm(cfg, batch=128, seq=32768, kind="decode")
    assert np.isclose(f_dec, 2 * cfg.n_active_params() * 128)
