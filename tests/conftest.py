"""Test session config. NOTE: no XLA device-count flags here — smoke tests
and benches must see exactly one CPU device (the 512-device flag belongs to
extras/dryrun.py alone). Multi-device tests spawn subprocesses."""
import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
