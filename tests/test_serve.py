"""Continuous-batching `QueryServer` (DESIGN.md §7): interleaved block
joins match sequential runs, executable sharing across bucket-mates,
per-query deadline isolation, the served/partial/failed split, and the
deprecation warnings behind the `repro.api` redesign.

Fast tests here never touch the device (config validation, admission
shedding, warnings); the end-to-end interleave/parity tests are slow, and
the sharded-backend parity run is a subprocess with 8 forced CPU devices
(per the dry-run isolation rule).
"""
import json
import pathlib
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.api import (
    GraphSession,
    QueryServer,
    ServerConfig,
    summarize_outcomes,
)
from repro.core.result import MatchStats
from repro.graphstore import PartitionedGraph, generators

from helpers import dfs_query, nx_oracle

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
TESTS = str(pathlib.Path(__file__).resolve().parent)


# ---------------------------------------------------------------- fast unit


def test_server_config_validation():
    with pytest.raises(ValueError):
        ServerConfig(max_inflight=0)
    with pytest.raises(ValueError):
        ServerConfig(block_rows=0)
    with pytest.raises(ValueError):
        ServerConfig(max_matches=-1)
    with pytest.raises(ValueError):
        ServerConfig(deadline_s=-0.5)


def test_api_exports_server_surface():
    import repro.api as api
    import repro.api.serve as serve_mod

    for name in ("QueryServer", "ServerConfig", "QueryOutcome", "Ticket",
                 "summarize_outcomes"):
        assert name in api.__all__
        assert getattr(api, name) is getattr(serve_mod, name)


def test_session_serve_builds_configured_server():
    g = generators.rmat(60, 180, 4, seed=0, symmetrize=True)
    s = GraphSession.open(g)
    server = s.serve(max_inflight=3, block_rows=64)
    assert isinstance(server, QueryServer)
    assert server.session is s
    assert server.config.max_inflight == 3
    assert server.config.block_rows == 64


def test_expired_deadline_sheds_at_admission_without_device_work():
    """A query whose deadline expired while queued is degraded per-query at
    admission — typed reason, no stream ever opened, server healthy."""
    g = generators.rmat(60, 180, 4, seed=0, symmetrize=True)
    s = GraphSession.open(g)
    rng = np.random.default_rng(1)
    q = None
    while q is None:
        q = dfs_query(g, rng, 3)
    server = s.serve(max_inflight=2)
    tickets = [server.submit(q, deadline_s=0.0) for _ in range(3)]
    server.run_until_idle()
    outcomes = [t.result(timeout=1) for t in tickets]
    assert all(o.status == "partial" for o in outcomes)
    assert all(o.stats.degrade_reason == "deadline" for o in outcomes)
    assert all(o.n_matches == 0 for o in outcomes)
    assert server.stats.setup_quanta == 0      # no exploration ever ran
    assert server.stats.join_quanta == 0
    assert server.stats.global_degradations == 0
    assert summarize_outcomes(outcomes) == {
        "served": 0, "partial": 3, "failed": 0, "n_matches": 0,
    }


def test_direct_engine_construction_warns():
    from repro.core.dist import DistributedMatcher  # noqa: F401
    from repro.core.engine import SubgraphMatcher

    g = generators.rmat(60, 180, 4, seed=0, symmetrize=True)
    pg = PartitionedGraph.build(g, 1)
    with pytest.warns(DeprecationWarning, match="GraphSession"):
        SubgraphMatcher(pg)


def test_session_open_does_not_warn():
    g = generators.rmat(60, 180, 4, seed=0, symmetrize=True)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        GraphSession.open(g)
    ours = [w for w in rec
            if issubclass(w.category, DeprecationWarning)
            and "GraphSession" in str(w.message)]
    assert not ours, "the facade must construct engines without warnings"


def test_dict_style_stats_access_warns():
    stats = MatchStats(backend="local")
    with pytest.warns(DeprecationWarning, match="stats.time_s"):
        assert stats["time_s"] == stats.time_s
    with pytest.warns(DeprecationWarning):
        assert stats.get("nope", 42) == 42
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert stats.time_s == 0.0  # attribute access stays clean


# ----------------------------------------------------- slow end-to-end local


@pytest.fixture(scope="module")
def session():
    g = generators.rmat(150, 500, 4, seed=7, symmetrize=True)
    return g, GraphSession.open(g)


@pytest.fixture(scope="module")
def queries(session):
    g, _ = session
    rng = np.random.default_rng(0)
    out = []
    while len(out) < 4:
        q = dfs_query(g, rng, 4)
        if q is not None:
            out.append(q)
    return out


@pytest.mark.slow
def test_interleaved_streams_match_sequential(session, queries):
    """>=4 in-flight streams, block quanta interleaved by the scheduler:
    every query's page union must equal its sequential run — disjoint
    pages, no duplicates, no cross-query contamination."""
    g, s = session
    server = s.serve(max_inflight=4, block_rows=8, max_matches=0)
    outcomes = server.serve(queries, child_cap=32)
    assert len(outcomes) == len(queries)
    assert server.stats.admitted == len(queries)
    for q, o in zip(queries, outcomes):
        ref = s.run(q, max_matches=0, child_cap=32)
        assert ref.complete
        assert o.status == "served", (o.status, o.error)
        assert o.result.complete
        got = set(map(tuple, o.rows.tolist()))
        assert got == set(map(tuple, ref.rows.tolist()))
        assert o.n_matches == len(got)  # disjoint pages, no duplicates
        assert o.stats.join_blocks >= 1
    # every dispatched join quantum is attributed to exactly one query
    assert server.stats.join_quanta == sum(
        o.stats.join_blocks for o in outcomes
    )
    assert server.stats.global_degradations == 0


@pytest.mark.slow
def test_bucket_mates_share_executables(session, queries):
    """Same-shape concurrent queries hit one bucket: after the first query
    warms the bucket, serving bucket-mates adds zero cache misses."""
    _, s = session
    q = queries[0]
    server = s.serve(max_inflight=4, block_rows=8, max_matches=0)
    server.serve([q], child_cap=32)          # first query pays the traces
    misses0 = s.cache.misses
    outcomes = server.serve([q, q, q, q], child_cap=32)
    assert all(o.status == "served" for o in outcomes)
    assert s.cache.misses == misses0, "bucket-mates must not re-trace"
    assert len({o.bucket for o in outcomes}) == 1


@pytest.mark.slow
def test_deadline_trip_never_degrades_bucket_mates(session, queries):
    """One in-flight query tripping its deadline degrades that query only:
    its bucket-mates finish complete and the server counts no global
    degradation (the per-query SLO the server exists to enforce)."""
    _, s = session
    q = queries[0]
    with s.serve(max_inflight=5, block_rows=8, max_matches=0) as server:
        mates = [server.submit(q, child_cap=32) for _ in range(4)]
        victim = server.submit(q, deadline_s=1e-6, child_cap=32)
        outcomes = [t.result(timeout=120) for t in mates]
        loser = victim.result(timeout=120)
    assert loser.status == "partial"
    assert loser.stats.degrade_reason == "deadline"
    for o in outcomes:
        assert o.status == "served", (o.status, o.error)
        assert o.result.complete
        assert o.stats.degrade_reason is None
    assert server.stats.global_degradations == 0


@pytest.mark.slow
def test_per_query_failure_is_isolated(session, queries):
    """An exception inside one query's quanta yields a failed outcome for
    that query; the others are served and the scheduler survives."""
    _, s = session
    server = s.serve(max_inflight=3, block_rows=8, max_matches=0)
    t_good = server.submit(queries[1], child_cap=32)
    t_bad = server.submit(queries[0], child_cap=32)
    # sabotage the bad entry so its setup quantum raises: a non-numeric
    # block size trips a TypeError inside open_stream
    with server._lock:
        entry = next(e for e in server._pending if e.ticket is t_bad)
    entry.block_rows = "bogus"
    server.run_until_idle()
    assert t_bad.result(timeout=1).status == "failed"
    assert "TypeError" in t_bad.result(timeout=1).error
    good = t_good.result(timeout=1)
    assert good.status == "served"
    assert server.stats.failed == 1 and server.stats.served == 1
    assert server.stats.global_degradations == 0


@pytest.mark.slow
def test_first_k_budget_stops_join_work(session, queries):
    """A budget-met stream is closed mid-flight: strictly fewer join quanta
    than full enumeration of the same query."""
    _, s = session
    q = queries[0]
    full_server = s.serve(max_inflight=1, block_rows=4, max_matches=0)
    (full,) = full_server.serve([q], child_cap=32)
    assert full.status == "served"
    if full.n_matches < 2 or full.stats.join_blocks < 2:
        pytest.skip("need >=2 non-empty blocks to observe an early stop")
    k_server = s.serve(max_inflight=1, block_rows=4, max_matches=1)
    (first,) = k_server.serve([q], child_cap=32)
    assert first.n_matches == 1
    assert first.stats.join_blocks < full.stats.join_blocks
    assert k_server.stats.join_quanta < full_server.stats.join_quanta


# ------------------------------------------------- slow sharded (8 devices)

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
sys.path.insert(0, %r)
from helpers import dfs_query, nx_oracle
from repro.api import GraphSession
from repro.graphstore import PartitionedGraph, generators

out = {}
g = generators.rmat(160, 520, 4, seed=3, symmetrize=True)
pg = PartitionedGraph.build(g, 8)
session = GraphSession.open(pg, backend="sharded")
rng = np.random.default_rng(5)
queries = []
while len(queries) < 4:
    q = dfs_query(g, rng, 4)
    if q is not None:
        queries.append(q)

server = session.serve(max_inflight=4, block_rows=8, max_matches=0)
outcomes = server.serve(queries, child_cap=32)
checks = []
for q, o in zip(queries, outcomes):
    got = set(map(tuple, o.rows.tolist()))
    checks.append(
        o.status == "served"
        and got == nx_oracle(g, q)
        and o.n_matches == len(got)
    )
out["sharded_interleave_exact"] = all(checks) and len(checks) == 4
out["global_degradations"] = server.stats.global_degradations
out["join_quanta_attributed"] = server.stats.join_quanta == sum(
    o.stats.join_blocks for o in outcomes
)
print(json.dumps(out))
""" % (TESTS,)


@pytest.mark.slow
def test_sharded_interleaved_streams_match_oracle():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["sharded_interleave_exact"]
    assert out["global_degradations"] == 0
    assert out["join_quanta_attributed"]
