"""§Perf optimized paths must match their baselines numerically."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig, MoEConfig
from repro.models import transformer as tf
from repro.models.layers import attention, blocked_decode_attention
from repro.models.moe import _moe_ffn_global, _moe_ffn_grouped, moe_schema
from repro.models.schema import init_params


def test_grouped_moe_matches_global():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=8.0,
                    dispatch_groups=4)
    sch = moe_schema(cfg, 1, 16, "float32")
    params = jax.tree.map(lambda a: a[0], init_params(sch, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16), jnp.float32)
    out_g, _ = _moe_ffn_global(x, params, cfg, "swiglu")
    out_l, _ = _moe_ffn_grouped(x, params, cfg, "swiglu")
    # capacity is ample → identical routing, identical math
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_l), atol=2e-5)


def test_blocked_decode_matches_attention():
    rng = np.random.default_rng(0)
    B, S, Nkv, G, H = 2, 64, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, Nkv * G, H)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Nkv, H)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Nkv, H)), jnp.float32)
    pos_q = jnp.asarray([37], jnp.int32)
    pos_k = jnp.arange(S, dtype=jnp.int32)
    for window in (None, 16):
        ref = attention(q, k, v, pos_q, pos_k, window=window)
        out = blocked_decode_attention(q, k, v, pos_q, pos_k, 8, window=window)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5
        )


def test_decode_kv_blocks_end_to_end():
    base = LMConfig(
        name="d", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
        d_ff=64, vocab_size=64, dtype="float32",
    )
    opt = base.__class__(**{**base.__dict__, "decode_kv_blocks": 4})
    params = tf.init(base, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    cache_a = tf.init_cache(base, 2, 8)
    cache_b = tf.init_cache(opt, 2, 8)
    for pos in range(8):
        la, cache_a = tf.decode_step(base, params, cache_a, toks[:, pos:pos+1], jnp.int32(pos))
        lb, cache_b = tf.decode_step(opt, params, cache_b, toks[:, pos:pos+1], jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-4, rtol=1e-4)
