"""Per-arch smoke tests (deliverable f): every assigned architecture's
reduced config runs one forward/train step on CPU — output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import all_archs
from repro.configs.base import GNNConfig, LMConfig, RecSysConfig
from repro.data import pipeline as data
from repro.graphstore import generators
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tf
from repro.models.schema import init_params
from repro.train import make_train_step

ARCHS = [a for a, e in all_archs().items() if e.family in ("lm", "gnn", "recsys")]


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_one_train_step(arch):
    entry = all_archs()[arch]
    cfg = entry.smoke()
    key = jax.random.PRNGKey(0)
    opt_cfg = optim.AdamWConfig(lr=1e-3)

    if isinstance(cfg, LMConfig):
        params = tf.init(cfg, key)
        batch = data.lm_batch(cfg, 2, 32, seed=0, step=0)
        logits, _, _ = tf.forward(cfg, params, jnp.asarray(batch["tokens"]))
        assert logits.shape == (2, 32, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    elif isinstance(cfg, GNNConfig):
        params = init_params(gnn_lib.gnn_schema(cfg), key)
        g = generators.rmat(64, 256, 4, seed=0)
        gb = data.gnn_full_batch(cfg, g, n_classes=cfg.n_classes, seed=0)
        batch = {"graph": gb}
        out = gnn_lib.forward(cfg, params, gb)
        assert out.shape[0] == g.n_nodes
        assert bool(jnp.isfinite(out).all())
    else:
        params = init_params(recsys_lib.recsys_schema(cfg), key)
        batch = data.recsys_batch(cfg, 8, seed=0, step=0)
        logit = recsys_lib.forward(
            cfg, params, jnp.asarray(batch["ids"]), jnp.asarray(batch["bag_mask"])
        )
        assert logit.shape == (8,)
        assert bool(jnp.isfinite(logit).all())

    opt_state = optim.init(opt_cfg, params)
    step = make_train_step(cfg, opt_cfg, warmup=1)
    new_params, new_state, metrics = jax.jit(step)(
        params, opt_state, batch, jnp.int32(1)
    )
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # params actually changed
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize(
    "arch", [a for a, e in all_archs().items() if e.family == "lm"]
)
def test_lm_decode_matches_forward(arch):
    entry = all_archs()[arch]
    cfg = entry.smoke()
    params = tf.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    logits_full, _, _ = tf.forward(cfg, params, toks)
    _, cache = tf.prefill(cfg, params, toks[:, :8])
    cache2 = tf.init_cache(cfg, 2, 16)
    data_ = tuple(
        jax.lax.dynamic_update_slice(z, c.astype(z.dtype), (0,) * z.ndim)
        for z, c in zip(cache2.data, cache.data)
    )
    cache2 = cache2.replace_data(data_)
    lg, _ = tf.decode_step(cfg, params, cache2, toks[:, 8:9], jnp.int32(8))
    ref = logits_full[:, 8].astype(jnp.float32)
    err = float(jnp.max(jnp.abs(lg[:, 0].astype(jnp.float32) - ref)))
    rel = err / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 5e-3, rel
