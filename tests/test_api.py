"""The `repro.api` facade: backend selection, compile/run split, batched
execution, streaming first-K pages, and local-vs-sharded parity.

The parity test runs in a subprocess so the main session keeps a single CPU
device (per the dry-run isolation rule).
"""
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

# whole-module: subprocess 8-device parity/stream runs take minutes
pytestmark = pytest.mark.slow

from repro.api import GraphSession
from repro.core import QueryGraph
from repro.graphstore import PartitionedGraph, generators

from helpers import dfs_query, nx_oracle

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(scope="module")
def session():
    g = generators.rmat(150, 500, 4, seed=7, symmetrize=True)
    return g, GraphSession.open(g)


@pytest.fixture(scope="module")
def queries(session):
    g, _ = session
    rng = np.random.default_rng(0)
    out = []
    while len(out) < 3:
        q = dfs_query(g, rng, 4)
        if q is not None:
            out.append(q)
    return out


def test_open_selects_local_backend(session):
    g, s = session
    assert s.backend == "local"
    assert s.pg.n_shards == 1
    # a multi-shard partition cannot be served by the local backend
    with pytest.raises(ValueError):
        GraphSession.open(PartitionedGraph.build(g, 4), backend="local")
    with pytest.raises(ValueError):
        GraphSession.open(g, backend="nonsense")


def test_facade_run_matches_oracle(session, queries):
    g, s = session
    for q in queries:
        res = s.run(q, max_matches=0)
        assert res.complete
        assert set(map(tuple, res.rows.tolist())) == nx_oracle(g, q)
        assert res.stats.backend == "local"
        assert res.stats.time_s > 0
        # dict-style deprecation bridge on MatchStats
        assert res.stats["time_s"] == res.stats.time_s
        with pytest.raises(KeyError):
            res.stats["not_a_field"]


def test_compile_run_split_reuses_executables(session, queries):
    _, s = session
    cq = s.compile(queries[0], max_matches=0)
    cq.run()
    h0, m0 = s.cache.hits, s.cache.misses
    res = cq.run()
    assert res.complete
    assert s.cache.misses == m0, "rerun of a compiled query must not re-jit"
    assert s.cache.hits > h0


def test_run_batch_equivalent_to_sequential(session, queries):
    _, s = session
    batch = s.run_batch(queries, max_matches=0)
    assert len(batch) == len(queries)
    for q, br in zip(queries, batch):
        sr = s.compile(q, max_matches=0).run()
        assert br.n_matches == sr.n_matches
        assert set(map(tuple, br.rows.tolist())) == set(map(tuple, sr.rows.tolist()))


def test_stream_pages_concat_equals_run(session, queries):
    _, s = session
    # generous caps so the compiled plan is already complete (streaming is
    # first-K: it never escalates capacities)
    cq = s.compile(queries[0], max_matches=0, child_cap=32)
    res = cq.run()
    assert res.complete and res.stats.retries == 0
    pages = list(cq.stream(page_size=16, max_matches=0))
    rows = (
        np.concatenate([p.rows for p in pages], axis=0)
        if pages
        else np.zeros((0, queries[0].n_nodes), np.int64)
    )
    assert all(p.complete for p in pages)
    assert all(p.rows.shape[0] == 16 for p in pages[:-1])  # full pages
    assert rows.shape[0] == res.n_matches  # disjoint pages, no duplicates
    assert set(map(tuple, rows.tolist())) == set(map(tuple, res.rows.tolist()))


def test_stream_first_k_stops_early(session, queries):
    _, s = session
    cq = s.compile(queries[0], max_matches=0, child_cap=32)
    full = cq.run()
    k = max(1, full.n_matches // 2)
    # a page size that does NOT divide k: the limit must hold mid-page too
    page = max(1, k // 3) + (1 if k % max(1, k // 3 + 1) == 0 else 0)
    got = list(cq.stream(page_size=page, max_matches=k))
    assert sum(p.rows.shape[0] for p in got) == min(k, full.n_matches)
    assert {tuple(r) for p in got for r in p.rows.tolist()} <= set(
        map(tuple, full.rows.tolist())
    )
    # explicit non-divisible pairing regardless of the graph's match count
    if full.n_matches >= 5:
        got2 = list(cq.stream(page_size=3, max_matches=5))
        assert [p.rows.shape[0] for p in got2] == [3, 2]


def test_stream_early_stop_skips_join_blocks(session, queries):
    """Stopping a stream early must skip the remaining blocks' join work,
    observable as strictly fewer block-join invocations on the engine."""
    _, s = session
    cq = s.compile(queries[0], max_matches=0, child_cap=32)
    full = cq.run(adaptive=False)
    assert full.complete
    if full.n_matches < 2:
        pytest.skip("need >=2 matches to observe an early stop")
    # size blocks so the valid rows of the blocked table span >=2 blocks
    n_min = min(full.stats.stwig_rows)
    block = max(1, n_min // 2)
    eng = s.engine
    c0 = eng.join_block_calls
    pages = list(cq.stream(page_size=1, max_matches=0, block_rows=block))
    full_calls = eng.join_block_calls - c0
    assert sum(p.rows.shape[0] for p in pages) == full.n_matches
    if full_calls < 2:
        pytest.skip("matches fit one block on this graph")
    c1 = eng.join_block_calls
    gen = cq.stream(page_size=1, max_matches=1, block_rows=block)
    assert next(gen, None) is not None
    gen.close()
    assert eng.join_block_calls - c1 < full_calls


def test_stream_reports_incomplete_on_overflow(session, queries):
    """Streaming never escalates capacities, so an overflowing plan must
    surface `complete=False` on some page — even if no rows survive."""
    _, s = session
    cq = s.compile(queries[0], max_matches=0, child_cap=2)
    ref = cq.run(adaptive=False)
    if ref.complete:
        pytest.skip("plan did not overflow on this graph")
    pages = list(cq.stream(page_size=16))
    assert pages, "incomplete stream yielded no pages at all"
    assert not all(p.complete for p in pages)


def test_adaptive_growth_through_facade(session, queries):
    g, s = session
    # child_cap=2 forces an initial overflow; adaptive replanning must recover
    res = s.compile(queries[0], max_matches=0, child_cap=2).run(adaptive=True)
    assert res.complete and res.stats.retries >= 1
    assert set(map(tuple, res.rows.tolist())) == nx_oracle(g, queries[0])


PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys, time
import numpy as np
sys.path.insert(0, %r)
from helpers import dfs_query, path_query
from repro.api import GraphSession
from repro.graphstore import PartitionedGraph, generators

g = generators.rmat(160, 520, 4, seed=3, symmetrize=True)
pg = PartitionedGraph.build(g, 8)
sharded = GraphSession.open(pg)            # auto -> sharded over 8 devices
local = GraphSession.open(g, backend="local")

out = {"backend": sharded.backend, "parity": [], "roots_parity": [],
       "stream_ok": [], "stream_complete": [], "early_skips_work": [],
       "stream_cache_reuse": [], "multi_stwig_streamed": False,
       "ttfp_s": [], "batch_ok": True}
rng = np.random.default_rng(5)
queries = []
while len(queries) < 1:
    q = dfs_query(g, rng, 4)
    if q is not None:
        queries.append(q)
# path queries decompose into >=2 STwigs: the streamed join chain and the
# gather-once load-set fetch are actually exercised
while len(queries) < 2:
    q = path_query(g, rng, 4)
    if q is not None and len(sharded.compile(q).plan.specs) >= 2:
        queries.append(q)

for q in queries:
    rs = sharded.run(q, max_matches=0)
    rl = local.run(q, max_matches=0)
    out["parity"].append(
        rs.complete and rl.complete
        and set(map(tuple, rs.rows.tolist())) == set(map(tuple, rl.rows.tolist()))
    )
    # stats parity: both backends populate stwig_roots (sharded reports the
    # per-shard max, so local — which sees the whole graph — is an upper bound)
    out["roots_parity"].append(
        len(rs.stats.stwig_roots) == len(rs.stats.rounds) == len(rl.stats.stwig_roots)
        and all(0 < s <= l for s, l in zip(rs.stats.stwig_roots, rl.stats.stwig_roots))
    )

    cq = sharded.compile(q, max_matches=0, child_cap=48)
    ref = cq.run(adaptive=False)
    assert ref.complete, "caps too small for stream comparison"
    eng = sharded.engine
    # provably-empty blocks are skipped host-side, so cut ~3 blocks from the
    # span of head rows that are valid on SOME shard (rows compact to the
    # front, so the span's first and last blocks are always non-empty)
    probe = eng._stream_setup(q, cq.plan)
    span = int(np.nonzero(probe.head_valid_any)[0][-1]) + 1
    assert span >= 4, "degenerate head table"
    B = span // 3 + 1
    c0 = eng.join_block_calls
    t0 = time.perf_counter()
    gen = cq.stream(page_size=16, max_matches=0, block_rows=B)
    first = next(gen, None)
    out["ttfp_s"].append(time.perf_counter() - t0)
    pages = ([first] if first is not None else []) + list(gen)
    full_calls = eng.join_block_calls - c0
    rows = (np.concatenate([p.rows for p in pages], axis=0)
            if pages else np.zeros((0, q.n_nodes), np.int64))
    out["stream_ok"].append(
        sum(p.n_rows for p in pages) == ref.n_matches  # disjoint pages
        and set(map(tuple, rows.tolist())) == set(map(tuple, ref.rows.tolist()))
    )
    out["stream_complete"].append(all(p.complete for p in pages))
    # consuming only the first page must invoke the block join step strictly
    # fewer times than producing every match does
    c1 = eng.join_block_calls
    gen = cq.stream(page_size=1, max_matches=1, block_rows=B)
    got_first = next(gen, None) is not None
    gen.close()
    early_calls = eng.join_block_calls - c1
    out["early_skips_work"].append(
        got_first and 1 <= early_calls < full_calls
    )
    # identical re-stream (first page is enough): the gather and block-join
    # steps were cached in the session's ExecutableCache, so no new traces
    misses1 = sharded.cache.misses
    gen = cq.stream(page_size=16, max_matches=0, block_rows=B)
    next(gen, None)
    gen.close()
    out["stream_cache_reuse"].append(sharded.cache.misses == misses1)
    out["multi_stwig_streamed"] |= len(cq.plan.specs) >= 2

batch = sharded.run_batch(queries, max_matches=0)
for q, br in zip(queries, batch):
    sr = sharded.run(q, max_matches=0)
    if set(map(tuple, br.rows.tolist())) != set(map(tuple, sr.rows.tolist())):
        out["batch_ok"] = False
print(json.dumps(out))
""" % (str(pathlib.Path(__file__).resolve().parent),)


@pytest.fixture(scope="module")
def parity_results():
    proc = subprocess.run(
        [sys.executable, "-c", PARITY_SCRIPT],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        timeout=1800,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_local_vs_sharded_parity(parity_results):
    assert parity_results["backend"] == "sharded"
    assert parity_results["parity"] and all(parity_results["parity"])


def test_sharded_stats_roots_parity(parity_results):
    # the sharded backend populates MatchStats.stwig_roots like the local one
    assert parity_results["roots_parity"] and all(parity_results["roots_parity"])


def test_sharded_stream_and_batch(parity_results):
    assert all(parity_results["stream_ok"])
    assert all(parity_results["stream_complete"])
    assert parity_results["batch_ok"]
    # at least one streamed query had a multi-STwig plan, so the gather-once
    # + block-join pipeline (not just head paging) was exercised
    assert parity_results["multi_stwig_streamed"]


def test_sharded_stream_is_pipelined(parity_results):
    # first-page-only consumption ran strictly fewer block-join device calls
    # than full consumption: early stopping skips real work inside shard_map
    assert parity_results["early_skips_work"] and all(
        parity_results["early_skips_work"]
    )
    # block steps retrace once per (schemas, caps, block size): an identical
    # re-stream hits the session ExecutableCache only
    assert all(parity_results["stream_cache_reuse"])
    # time-to-first-page smoke: the first page materialized and was timed
    assert all(t > 0 for t in parity_results["ttfp_s"])
