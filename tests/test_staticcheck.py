"""Seeded-violation suite for `repro.analysis.staticcheck`.

Every rule must (a) fire on a planted violation and (b) stay silent on the
real repository — a lint that can't catch its own fixture, or that cries
wolf on the clean tree, gates nothing.
"""
import pathlib
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.staticcheck import (
    archlint,
    cachekeys,
    collective_safety,
    contracts,
    costmodel,
    run_all,
)
from repro.analysis.staticcheck.findings import RULES, report_json
from repro.core import backend as backend_lib
from repro.core.backend import OpContract

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _rules_of(findings):
    return {f.rule for f in findings}


def _write(root: pathlib.Path, rel: str, body: str) -> None:
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(body))


@pytest.fixture()
def fixture_repo(tmp_path):
    """A minimal repo skeleton the AST passes can walk."""
    _write(tmp_path, "src/repro/__init__.py", "")
    _write(tmp_path, "src/repro/core/__init__.py", "")
    _write(tmp_path, "tests/test_ok.py", "import repro.core\n")
    return tmp_path


# ------------------------------------------------------------ archlint rules
def test_bitset_twiddling_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/twiddle.py", """\
        def word_of(i):
            return i >> 5, i & 31, i % 32
    """)
    _write(fixture_repo, "tests/test_ok.py",
           "import repro.core.twiddle\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "bitset-twiddling"]
    assert len(fs) == 3 and all("twiddle.py" in f.path for f in fs)


def test_bitset_twiddling_allowed_in_kernels_bitset(fixture_repo):
    _write(fixture_repo, "src/repro/kernels/__init__.py", "")
    _write(fixture_repo, "src/repro/kernels/bitset/__init__.py", "")
    _write(fixture_repo, "src/repro/kernels/bitset/impl.py", """\
        def word_of(i):
            return i >> 5
    """)
    _write(fixture_repo, "tests/test_ok.py",
           "import repro.kernels.bitset.impl\n")
    assert not [f for f in archlint.run(str(fixture_repo))
                if f.rule == "bitset-twiddling"]


def test_module_jit_state_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/jitstate.py", """\
        import functools
        import jax

        @functools.lru_cache(maxsize=None)
        def build(n):
            return n

        square = jax.jit(lambda x: x * x)
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.jitstate\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "module-jit-state"]
    assert len(fs) == 2  # the decorator AND the import-time jit


def test_direct_engine_construction_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/sneaky.py", """\
        from repro.core.engine import SubgraphMatcher

        def make(pg):
            return SubgraphMatcher(pg)
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.sneaky\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "direct-engine-construction"]
    assert len(fs) == 1 and fs[0].line == 4


def test_stream_host_sync_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/consumer.py", """\
        import jax

        def drain(compiled):
            out = []
            for page in compiled.stream(page_size=8):
                out.append(jax.device_get(page.rows))
            return out
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.consumer\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "stream-host-sync"]
    assert len(fs) == 1


def test_missing_slow_marker_planted(fixture_repo):
    _write(fixture_repo, "tests/test_spawns.py", """\
        import subprocess

        def test_heavy():
            subprocess.run(["true"])
    """)
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "missing-slow-marker"]
    assert len(fs) == 1
    # module-level pytestmark silences it
    _write(fixture_repo, "tests/test_spawns.py", """\
        import subprocess
        import pytest

        pytestmark = pytest.mark.slow

        def test_heavy():
            subprocess.run(["true"])
    """)
    assert not [f for f in archlint.run(str(fixture_repo))
                if f.rule == "missing-slow-marker"]


def test_orphan_module_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/dead.py", "VALUE = 1\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "orphan-module"]
    assert [f.path for f in fs] == ["src/repro/core/dead.py"]
    # the extras/ quarantine is exempt
    _write(fixture_repo, "src/repro/extras/__init__.py", "")
    _write(fixture_repo, "src/repro/extras/dead2.py", "VALUE = 2\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "orphan-module"]
    assert [f.path for f in fs] == ["src/repro/core/dead.py"]


def test_unused_import_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/lazy.py", """\
        import os
        import sys

        def cwd():
            return os.getcwd()
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.lazy\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "unused-import"]
    assert len(fs) == 1 and "`sys`" in fs[0].message


def test_suppression_comment_silences_rule(fixture_repo):
    _write(fixture_repo, "src/repro/core/twiddle.py", """\
        def word_of(i):
            return i >> 5  # staticcheck: ignore[bitset-twiddling]
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.twiddle\n")
    assert not [f for f in archlint.run(str(fixture_repo))
                if f.rule == "bitset-twiddling"]


# ------------------------------------------------------------- cache keys
def test_cache_key_coverage_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/leaky.py", """\
        import jax

        class Engine:
            def fn(self, spec, cap):
                return self.cache.get(
                    ("match", spec),
                    lambda: jax.jit(lambda x: x[:cap]),
                )
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.leaky\n")
    fs = cachekeys.check_cache_keys(fixture_repo)
    assert len(fs) == 1 and "'cap'" in fs[0].message


def test_cache_key_coverage_assigned_key_and_named_builder(fixture_repo):
    _write(fixture_repo, "src/repro/core/tight.py", """\
        import jax

        class Engine:
            def fn(self, spec, cap):
                def build():
                    return jax.jit(lambda x: x[:cap])

                key = ("match", spec, cap)
                return self.cache.get(key, build)
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.tight\n")
    assert not cachekeys.check_cache_keys(fixture_repo)


# --------------------------------------------------------- jaxpr contracts
class _FakeKernels:
    """Minimal stand-in for a `Kernels` backend, one op per test."""

    name = "_staticcheck_test"

    def __init__(self, fn):
        self._fn = fn

    def op(self, x):
        return self._fn(x)


def _fake_contract(out_dtypes):
    return OpContract(
        "op",
        lambda: ((jax.ShapeDtypeStruct((8,), jnp.int32),), {}),
        out_dtypes,
    )


def _check_fake(fn, out_dtypes):
    """Register a throwaway backend, run the contract pass on it alone."""
    name = _FakeKernels.name
    backend_lib.register_backend(
        name, lambda: _FakeKernels(fn), contracts=(_fake_contract(out_dtypes),)
    )
    try:
        return contracts.check_kernel_contracts([name])
    finally:
        backend_lib._REGISTRY.pop(name, None)
        backend_lib._INSTANCES.pop(name, None)
        backend_lib._CONTRACTS.pop(name, None)


def test_jaxpr_out_dtype_planted():
    fs = _check_fake(lambda x: x.astype(jnp.float32), out_dtypes=("int32",))
    assert _rules_of(fs) == {"jaxpr-out-dtype"}
    assert "float32" in fs[0].message


def test_jaxpr_out_dtype_trace_failure_is_a_finding():
    def broken(x):
        raise TypeError("no abstract trace for you")

    fs = _check_fake(broken, out_dtypes=("int32",))
    assert _rules_of(fs) == {"jaxpr-out-dtype"}
    assert "failed to trace" in fs[0].message


def test_jaxpr_dtype_width_planted():
    with jax.experimental.enable_x64():
        fs = _check_fake(
            lambda x: x.astype(jnp.float64), out_dtypes=("float64",)
        )
    assert _rules_of(fs) == {"jaxpr-dtype-width"}


def test_jaxpr_banned_primitive_planted():
    def leaky(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct((8,), jnp.int32), x
        )

    fs = _check_fake(leaky, out_dtypes=("int32",))
    assert "jaxpr-banned-primitive" in _rules_of(fs)


def test_real_contracts_trace_clean_on_all_backends():
    # under ambient x64 restrict to jnp, matching the CLI's --x64 policy
    # (pallas interpret-mode runs its grid loop in int64 by itself)
    backends = ["jnp"] if jax.config.jax_enable_x64 else None
    assert contracts.check_kernel_contracts(backends) == []


# ------------------------------------------------- collective safety (d)
def _shard_trace(body, mesh_axes, in_specs, out_specs, *args):
    """Trace `body` under a shard_map on a mesh built from this process's
    single device (axis sizes 1 — the analysis is static, sizes only name
    the axes)."""
    import numpy as np

    from jax.sharding import Mesh
    from repro.compat import shard_map

    devs = np.array(jax.devices()[:1]).reshape((1,) * len(mesh_axes))
    mesh = Mesh(devs, tuple(mesh_axes))
    f = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    return jax.make_jaxpr(f)(*args)


def _one_finding(findings, rule_id):
    assert [f.rule for f in findings] == [rule_id], \
        "\n".join(str(f) for f in findings)
    # the acceptance path: the finding must survive into --json output
    assert rule_id in report_json(findings)
    return findings[0]


def test_collective_divergent_control_planted():
    from jax.sharding import PartitionSpec as P

    def body(v):
        i = jax.lax.axis_index("data")
        return jax.lax.cond(
            i > 0, lambda u: jax.lax.psum(u, "data"), lambda u: u, v
        )

    j = _shard_trace(body, ("data",), P("data"), P("data"),
                     jnp.arange(8, dtype=jnp.int32))
    f = _one_finding(
        collective_safety.check_collective_safety(j, "fx"),
        "coll-divergent-control",
    )
    assert "psum" in f.message


def test_collective_ppermute_bijection_planted():
    from jax.sharding import PartitionSpec as P

    def body(v):  # empty perm: nobody sends, everybody zero-fills
        return jax.lax.ppermute(v, "data", perm=[])

    j = _shard_trace(body, ("data",), P("data"), P("data"),
                     jnp.arange(8, dtype=jnp.int32))
    _one_finding(
        collective_safety.check_collective_safety(j, "fx"),
        "coll-ppermute-bijection",
    )


def test_collective_axis_name_planted():
    from jax.sharding import PartitionSpec as P

    def body(v):  # "model" is a mesh axis, but not the engine's axis
        return jax.lax.psum(v, "model")

    j = _shard_trace(body, ("data", "model"), P("data"), P("data"),
                     jnp.arange(8, dtype=jnp.int32).reshape(8, 1))
    _one_finding(
        collective_safety.check_collective_safety(
            j, "fx", allowed_axes=("data",)
        ),
        "coll-axis-name",
    )


def test_collective_head_gather_planted():
    from jax.sharding import PartitionSpec as P

    def body(h, t):  # Theorem 5: the head table must never be gathered
        g = jax.lax.all_gather(h, "data", tiled=True)
        return g + t

    x = jnp.arange(8, dtype=jnp.int32)
    j = _shard_trace(body, ("data",), (P("data"), P("data")), P("data"),
                     x, x)
    _one_finding(
        collective_safety.check_collective_safety(j, "fx", head_invars=(0,)),
        "coll-head-gather",
    )
    # the same program is clean when the gathered operand is not the head
    assert collective_safety.check_collective_safety(
        j, "fx", head_invars=(1,)
    ) == []


def test_collective_clean_on_benign_body():
    from jax.sharding import PartitionSpec as P

    def body(v):  # full-axis reduce + bijective self-permute: all legal
        s = jax.lax.psum(v, "data")
        p = jax.lax.ppermute(v, "data", perm=[(0, 0)])
        return s + p

    j = _shard_trace(body, ("data",), P("data"), P("data"),
                     jnp.arange(8, dtype=jnp.int32))
    reports = []
    assert collective_safety.check_collective_safety(
        j, "fx", allowed_axes=("data",), reports=reports
    ) == []
    assert reports[0].collectives == ["psum", "ppermute"]


def test_head_taints_for_key_positions():
    schemas = ((0, 1), (1, 2), (2, 3))
    assert collective_safety.head_taints_for_key(
        ("dist_join", schemas, (0, 1, 2), 1, 64, 4, (8, 8, 8), None, "jnp")
    ) == (1, 4)
    assert collective_safety.head_taints_for_key(
        ("dist_gather", 3, 2, (8, 8, 8), None)
    ) == (2, 5)
    assert collective_safety.head_taints_for_key(
        ("dist_join_block", schemas, (0, 1), 64, 4, 8, (8, 8), 16, "jnp")
    ) == (0, 1)
    assert collective_safety.head_taints_for_key(("dist_match", "x")) == ()


# ------------------------------------------------------- cost model (e)
def _est(target, peak=1.0, flops=1.0, coll=0.0):
    return costmodel.CostEstimate(
        target=target, peak_bytes=peak, flops=flops,
        collective_bytes=coll, collective_by_kind={},
    )


_TEST_BUDGETS = {
    "linear_slack": 2.0,
    "entries": {
        "engine:test:jnp:match": {
            "peak_bytes": 1000, "flops": 5000, "collective_bytes": 100,
        },
    },
}


def test_cost_budget_overflow_planted():
    f = _one_finding(
        costmodel.check_budgets(
            [_est("engine:test:jnp:match", peak=1500, flops=10, coll=0)],
            _TEST_BUDGETS,
        ),
        "cost-budget-exceeded",
    )
    assert "peak_bytes" in f.message


def test_cost_budget_missing_fails_closed():
    _one_finding(
        costmodel.check_budgets(
            [_est("engine:test:jnp:new_entry_point", peak=1)], _TEST_BUDGETS,
        ),
        "cost-budget-missing",
    )


def test_cost_budget_within_ceiling_is_clean():
    assert costmodel.check_budgets(
        [_est("engine:test:jnp:match", peak=999, flops=4999, coll=99)],
        _TEST_BUDGETS,
    ) == []


def test_cost_superlinear_memory_planted():
    small = [_est("engine:test:jnp:join", peak=1000)]
    # quadratic structure: 4x graph -> 16x bytes, bound is 2.0 * 4 = 8x
    _one_finding(
        costmodel.check_linear_memory(
            small, [_est("engine:test:jnp:join", peak=16000)],
            size_ratio=4.0, slack=2.0,
        ),
        "cost-superlinear-memory",
    )
    assert costmodel.check_linear_memory(
        small, [_est("engine:test:jnp:join", peak=4000)],
        size_ratio=4.0, slack=2.0,
    ) == []


def test_cost_estimate_counts_quadratic_intermediate():
    """The liveness peak must see a materialized O(n^2) outer product."""
    n = 64

    def outer(a, b):
        z = a[:, None] * b[None, :]          # (n, n) float32
        return z.sum()

    est = costmodel.estimate(
        jax.make_jaxpr(outer)(
            jnp.ones((n,), jnp.float32), jnp.ones((n,), jnp.float32)
        ),
        target="fx",
    )
    assert est.peak_bytes >= n * n * 4


def test_checked_in_budgets_cover_probe_targets():
    """Every engine×kernels×entry-point the probe records has a budget row
    (the fail-closed contract, checked without running the probe)."""
    budgets = costmodel.load_budgets()
    targets = set(budgets["entries"])
    for eng, heads in (
        ("local", ("match", "join")),
        ("sharded", ("dist_match", "dist_join", "dist_gather",
                     "dist_join_block")),
    ):
        for k in ("jnp", "pallas-interpret"):
            for h in heads:
                assert f"engine:{eng}:{k}:{h}" in targets


def test_cost_flops_agree_with_hlo_within_10pct():
    """Acceptance: jaxpr FLOP estimates vs XLA cost_analysis on the
    benchmarked kernels (matmul-shaped cin layer + the join probe's sort)."""
    from repro.kernels.cin.ref import cin_layer_reference

    xk = jnp.ones((4, 8, 16), jnp.float32)
    x0 = jnp.ones((4, 4, 16), jnp.float32)
    w = jnp.ones((32, 8), jnp.float32)
    r = costmodel.hlo_cross_check(cin_layer_reference, xk, x0, w)
    assert r["hlo_flops"] > 0
    assert abs(r["est_flops"] - r["hlo_flops"]) <= 0.1 * r["hlo_flops"], r


# ----------------------------------------------------------- clean repo
def test_static_passes_clean_on_repo():
    """The repo's own tree carries zero findings (the CI gate); the engine
    probe is covered separately (`test_retrace.py`) because it executes."""
    backends = ["jnp"] if jax.config.jax_enable_x64 else None
    fs = run_all(REPO_ROOT, engines=False, kernel_backends=backends)
    assert fs == [], "\n".join(str(f) for f in fs)


def test_every_rule_has_a_registered_description():
    assert len(RULES) >= 15  # incl. the collective-safety + cost rules
    for r in RULES.values():
        assert r.layer and r.description
