"""Seeded-violation suite for `repro.analysis.staticcheck`.

Every rule must (a) fire on a planted violation and (b) stay silent on the
real repository — a lint that can't catch its own fixture, or that cries
wolf on the clean tree, gates nothing.
"""
import pathlib
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.staticcheck import archlint, cachekeys, contracts, run_all
from repro.analysis.staticcheck.findings import RULES
from repro.core import backend as backend_lib
from repro.core.backend import OpContract

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _rules_of(findings):
    return {f.rule for f in findings}


def _write(root: pathlib.Path, rel: str, body: str) -> None:
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(body))


@pytest.fixture()
def fixture_repo(tmp_path):
    """A minimal repo skeleton the AST passes can walk."""
    _write(tmp_path, "src/repro/__init__.py", "")
    _write(tmp_path, "src/repro/core/__init__.py", "")
    _write(tmp_path, "tests/test_ok.py", "import repro.core\n")
    return tmp_path


# ------------------------------------------------------------ archlint rules
def test_bitset_twiddling_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/twiddle.py", """\
        def word_of(i):
            return i >> 5, i & 31, i % 32
    """)
    _write(fixture_repo, "tests/test_ok.py",
           "import repro.core.twiddle\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "bitset-twiddling"]
    assert len(fs) == 3 and all("twiddle.py" in f.path for f in fs)


def test_bitset_twiddling_allowed_in_kernels_bitset(fixture_repo):
    _write(fixture_repo, "src/repro/kernels/__init__.py", "")
    _write(fixture_repo, "src/repro/kernels/bitset/__init__.py", "")
    _write(fixture_repo, "src/repro/kernels/bitset/impl.py", """\
        def word_of(i):
            return i >> 5
    """)
    _write(fixture_repo, "tests/test_ok.py",
           "import repro.kernels.bitset.impl\n")
    assert not [f for f in archlint.run(str(fixture_repo))
                if f.rule == "bitset-twiddling"]


def test_module_jit_state_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/jitstate.py", """\
        import functools
        import jax

        @functools.lru_cache(maxsize=None)
        def build(n):
            return n

        square = jax.jit(lambda x: x * x)
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.jitstate\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "module-jit-state"]
    assert len(fs) == 2  # the decorator AND the import-time jit


def test_direct_engine_construction_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/sneaky.py", """\
        from repro.core.engine import SubgraphMatcher

        def make(pg):
            return SubgraphMatcher(pg)
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.sneaky\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "direct-engine-construction"]
    assert len(fs) == 1 and fs[0].line == 4


def test_stream_host_sync_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/consumer.py", """\
        import jax

        def drain(compiled):
            out = []
            for page in compiled.stream(page_size=8):
                out.append(jax.device_get(page.rows))
            return out
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.consumer\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "stream-host-sync"]
    assert len(fs) == 1


def test_missing_slow_marker_planted(fixture_repo):
    _write(fixture_repo, "tests/test_spawns.py", """\
        import subprocess

        def test_heavy():
            subprocess.run(["true"])
    """)
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "missing-slow-marker"]
    assert len(fs) == 1
    # module-level pytestmark silences it
    _write(fixture_repo, "tests/test_spawns.py", """\
        import subprocess
        import pytest

        pytestmark = pytest.mark.slow

        def test_heavy():
            subprocess.run(["true"])
    """)
    assert not [f for f in archlint.run(str(fixture_repo))
                if f.rule == "missing-slow-marker"]


def test_orphan_module_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/dead.py", "VALUE = 1\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "orphan-module"]
    assert [f.path for f in fs] == ["src/repro/core/dead.py"]
    # the extras/ quarantine is exempt
    _write(fixture_repo, "src/repro/extras/__init__.py", "")
    _write(fixture_repo, "src/repro/extras/dead2.py", "VALUE = 2\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "orphan-module"]
    assert [f.path for f in fs] == ["src/repro/core/dead.py"]


def test_unused_import_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/lazy.py", """\
        import os
        import sys

        def cwd():
            return os.getcwd()
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.lazy\n")
    fs = [f for f in archlint.run(str(fixture_repo))
          if f.rule == "unused-import"]
    assert len(fs) == 1 and "`sys`" in fs[0].message


def test_suppression_comment_silences_rule(fixture_repo):
    _write(fixture_repo, "src/repro/core/twiddle.py", """\
        def word_of(i):
            return i >> 5  # staticcheck: ignore[bitset-twiddling]
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.twiddle\n")
    assert not [f for f in archlint.run(str(fixture_repo))
                if f.rule == "bitset-twiddling"]


# ------------------------------------------------------------- cache keys
def test_cache_key_coverage_planted(fixture_repo):
    _write(fixture_repo, "src/repro/core/leaky.py", """\
        import jax

        class Engine:
            def fn(self, spec, cap):
                return self.cache.get(
                    ("match", spec),
                    lambda: jax.jit(lambda x: x[:cap]),
                )
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.leaky\n")
    fs = cachekeys.check_cache_keys(fixture_repo)
    assert len(fs) == 1 and "'cap'" in fs[0].message


def test_cache_key_coverage_assigned_key_and_named_builder(fixture_repo):
    _write(fixture_repo, "src/repro/core/tight.py", """\
        import jax

        class Engine:
            def fn(self, spec, cap):
                def build():
                    return jax.jit(lambda x: x[:cap])

                key = ("match", spec, cap)
                return self.cache.get(key, build)
    """)
    _write(fixture_repo, "tests/test_ok.py", "import repro.core.tight\n")
    assert not cachekeys.check_cache_keys(fixture_repo)


# --------------------------------------------------------- jaxpr contracts
class _FakeKernels:
    """Minimal stand-in for a `Kernels` backend, one op per test."""

    name = "_staticcheck_test"

    def __init__(self, fn):
        self._fn = fn

    def op(self, x):
        return self._fn(x)


def _fake_contract(out_dtypes):
    return OpContract(
        "op",
        lambda: ((jax.ShapeDtypeStruct((8,), jnp.int32),), {}),
        out_dtypes,
    )


def _check_fake(fn, out_dtypes):
    """Register a throwaway backend, run the contract pass on it alone."""
    name = _FakeKernels.name
    backend_lib.register_backend(
        name, lambda: _FakeKernels(fn), contracts=(_fake_contract(out_dtypes),)
    )
    try:
        return contracts.check_kernel_contracts([name])
    finally:
        backend_lib._REGISTRY.pop(name, None)
        backend_lib._INSTANCES.pop(name, None)
        backend_lib._CONTRACTS.pop(name, None)


def test_jaxpr_out_dtype_planted():
    fs = _check_fake(lambda x: x.astype(jnp.float32), out_dtypes=("int32",))
    assert _rules_of(fs) == {"jaxpr-out-dtype"}
    assert "float32" in fs[0].message


def test_jaxpr_out_dtype_trace_failure_is_a_finding():
    def broken(x):
        raise TypeError("no abstract trace for you")

    fs = _check_fake(broken, out_dtypes=("int32",))
    assert _rules_of(fs) == {"jaxpr-out-dtype"}
    assert "failed to trace" in fs[0].message


def test_jaxpr_dtype_width_planted():
    with jax.experimental.enable_x64():
        fs = _check_fake(
            lambda x: x.astype(jnp.float64), out_dtypes=("float64",)
        )
    assert _rules_of(fs) == {"jaxpr-dtype-width"}


def test_jaxpr_banned_primitive_planted():
    def leaky(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct((8,), jnp.int32), x
        )

    fs = _check_fake(leaky, out_dtypes=("int32",))
    assert "jaxpr-banned-primitive" in _rules_of(fs)


def test_real_contracts_trace_clean_on_all_backends():
    # under ambient x64 restrict to jnp, matching the CLI's --x64 policy
    # (pallas interpret-mode runs its grid loop in int64 by itself)
    backends = ["jnp"] if jax.config.jax_enable_x64 else None
    assert contracts.check_kernel_contracts(backends) == []


# ----------------------------------------------------------- clean repo
def test_static_passes_clean_on_repo():
    """The repo's own tree carries zero findings (the CI gate); the engine
    probe is covered separately (`test_retrace.py`) because it executes."""
    backends = ["jnp"] if jax.config.jax_enable_x64 else None
    fs = run_all(REPO_ROOT, engines=False, kernel_backends=backends)
    assert fs == [], "\n".join(str(f) for f in fs)


def test_every_rule_has_a_registered_description():
    assert len(RULES) >= 8
    for r in RULES.values():
        assert r.layer and r.description
