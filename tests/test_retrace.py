"""Retrace detector (staticcheck pass b): unit level and end to end.

End-to-end acceptance: with ``REPRO_CHECK_RETRACE=1``, run + stream +
re-stream on both engine backends and both CPU kernel backends without a
single logical cache key tracing twice — `ExecutableCache.get` raises
`RetraceError` the moment one does, and `assert_no_retrace` additionally
catches jitted executables that silently re-traced under one key.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import GraphSession
from repro.core import QueryGraph
from repro.core.cache import ExecutableCache, RetraceError
from repro.graphstore import generators

QUERY = QueryGraph.build([0, 1, 2, 3], [(0, 1), (1, 2), (2, 3)])


def _graph():
    return generators.rmat(120, 420, 4, seed=3, symmetrize=True)


# ------------------------------------------------------------------ unit
def test_cache_raises_on_second_trace_of_one_key():
    cache = ExecutableCache(check_retrace=True)
    cache.get(("k", 1), lambda: "exe")
    cache.get(("k", 1), lambda: "exe")  # hit: fine
    cache.clear()  # dropping executables does not erase trace history
    with pytest.raises(RetraceError):
        cache.get(("k", 1), lambda: "exe")


def test_cache_records_duplicates_when_not_raising():
    cache = ExecutableCache(check_retrace=False)
    cache.get(("k", 1), lambda: "exe")
    cache.clear()
    cache.get(("k", 1), lambda: "exe")
    assert cache.duplicate_traces() == [("k", 1)]
    with pytest.raises(RetraceError):
        cache.assert_no_retrace()


def test_cache_env_opt_in(monkeypatch):
    monkeypatch.setenv("REPRO_CHECK_RETRACE", "1")
    assert ExecutableCache().check_retrace
    monkeypatch.setenv("REPRO_CHECK_RETRACE", "0")
    assert not ExecutableCache().check_retrace


def test_silent_jit_retrace_under_one_key_is_caught():
    """A static argument that escapes the cache key: one key, two traces."""
    cache = ExecutableCache(check_retrace=True)
    fn = cache.get(("squash",), lambda: jax.jit(lambda x: x * 2))
    fn(jnp.zeros((4,), jnp.int32))
    fn(jnp.zeros((8,), jnp.int32))  # new shape -> silent second trace
    assert cache.retraced_executables()
    with pytest.raises(RetraceError):
        cache.assert_no_retrace()


def test_recorder_sees_invocations():
    cache = ExecutableCache()
    seen = []
    cache.recorder = lambda key, fn, a, kw: seen.append(key)
    fn = cache.get(("f",), lambda: (lambda x: x + 1))
    assert fn(1) == 2
    fn = cache.get(("f",), lambda: (lambda x: x + 1))  # hit, still wrapped
    assert fn(2) == 3
    assert seen == [("f",), ("f",)]


# ------------------------------------------------------------ end to end
@pytest.mark.parametrize("kernels", ["jnp", "pallas-interpret"])
def test_run_stream_restream_traces_each_key_once(monkeypatch, kernels):
    if kernels == "pallas-interpret":
        pytest.importorskip("jax.experimental.pallas")
    monkeypatch.setenv("REPRO_CHECK_RETRACE", "1")
    with GraphSession.open(_graph(), kernels=kernels) as s:
        assert s.cache.check_retrace  # env picked up at session open
        compiled = s.compile(QUERY, max_matches=0)
        res = compiled.run(adaptive=False)
        pages = [p.rows for p in compiled.stream(page_size=16)]
        misses_after_stream = s.cache.misses
        re_pages = [p.rows for p in compiled.stream(page_size=16)]
        # the re-stream built nothing new: every executable was a cache hit
        assert s.cache.misses == misses_after_stream
        s.cache.assert_no_retrace()
    if res.complete:
        rows = np.concatenate([np.zeros((0, 4), np.int64), *pages])
        assert rows.shape[0] == res.rows.shape[0]
        assert [r.tolist() for r in re_pages] == [r.tolist() for r in pages]


def test_sharded_run_stream_restream_traces_each_key_once(monkeypatch):
    monkeypatch.setenv("REPRO_CHECK_RETRACE", "1")
    with GraphSession.open(_graph(), backend="sharded") as s:
        compiled = s.compile(QUERY, max_matches=0)
        compiled.run(adaptive=False)
        for _ in compiled.stream(page_size=16):
            pass
        for _ in compiled.stream(page_size=16):
            pass
        s.cache.assert_no_retrace()


def test_engine_probe_is_clean():
    """The staticcheck engine probe (recorder + jaxpr walk) on the cheap
    combination; the CLI covers the full matrix."""
    from repro.analysis.staticcheck import engines

    assert engines.probe_engine("local", "jnp") == []
