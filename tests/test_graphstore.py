"""Graph substrate: partition roundtrip, label index, bitsets (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.graphstore import (
    LabelIndex,
    PartitionedGraph,
    bitset_test_np,
    generators,
    pack_bitset,
    unpack_bitset,
)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(20, 300),
    mdeg=st.integers(1, 8),
    s=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 99),
    mode=st.sampled_from(["hash", "range"]),
)
def test_partition_preserves_graph(n, mdeg, s, seed, mode):
    g = generators.rmat(n, mdeg * n, 5, seed=seed)
    pg = PartitionedGraph.build(g, s, mode=mode)
    # edge multiset preserved under relabeling
    orig = set()
    for v in range(g.n_nodes):
        for u in g.neighbors(v):
            orig.add((v, int(u)))
    recon = set()
    for sh in range(s):
        ne = int(pg.n_local_edges[sh])
        src_new = sh * pg.cap + pg.edge_src[sh, :ne].astype(np.int64)
        dst_new = pg.indices[sh, :ne].astype(np.int64)
        for a, b in zip(src_new, dst_new):
            recon.add((int(pg.new_to_old[a]), int(pg.new_to_old[b])))
    assert orig == recon
    # labels preserved
    for v in range(g.n_nodes):
        assert pg.all_labels[pg.old_to_new[v]] == g.labels[v]
    # ghost entry is the invalid label
    assert pg.all_labels[-1] == g.n_labels


def test_label_index_complete():
    g = generators.rmat(500, 2000, 7, seed=1)
    pg = PartitionedGraph.build(g, 4)
    li = LabelIndex(pg)
    total = 0
    for sh in range(4):
        for l in range(7):
            ids = li.get_ids(sh, l)
            assert (pg.labels[sh][ids] == l).all()
            total += len(ids)
    assert total == g.n_nodes


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 2000), p=st.floats(0.0, 1.0), seed=st.integers(0, 99))
def test_bitset_roundtrip(n, p, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(n) < p
    words = pack_bitset(mask)
    assert (unpack_bitset(words, n) == mask).all()
    ids = rng.integers(0, n, size=min(n, 64))
    assert (bitset_test_np(words, ids) == mask[ids]).all()
