"""End-to-end behaviour tests: graph build → plan → distributed-grade match
→ results verified; plus a short LM training run that actually learns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# whole-module: end-to-end match + LM training runs take minutes
pytestmark = pytest.mark.slow

from repro import optim
from repro.configs.base import LMConfig
from repro.core import QueryGraph, SubgraphMatcher
from repro.data import lm_batch
from repro.graphstore import PartitionedGraph, generators
from repro.models import transformer as tf
from repro.train import make_train_step

from helpers import nx_oracle


def test_end_to_end_query_pipeline():
    g = generators.rmat(400, 1600, 5, seed=11)
    m = SubgraphMatcher(PartitionedGraph.build(g, 1))
    q = QueryGraph.build([0, 1, 2, 1], [(0, 1), (1, 2), (2, 3), (0, 3)])  # 4-cycle
    res = m.match(q, max_matches=0)
    assert res.complete
    got = set(map(tuple, res.rows.tolist()))
    assert got == nx_oracle(g, q)
    # every returned row is a valid embedding
    for row in res.rows[:20]:
        for u, v in q.edges:
            assert row[v] in g.neighbors(row[u])


def test_lm_actually_learns():
    cfg = LMConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=257, dtype="float32",
    )
    params = tf.init(cfg, jax.random.PRNGKey(0))
    opt_cfg = optim.AdamWConfig(lr=3e-3)
    opt_state = optim.init(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=60, warmup=5))
    losses = []
    for i in range(60):
        batch = {"tokens": jnp.asarray(lm_batch(cfg, 8, 64, seed=0, step=i % 4)["tokens"])}
        params, opt_state, metrics = step(params, opt_state, batch, jnp.int32(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_microbatch_accumulation_matches_full_batch():
    cfg = LMConfig(
        name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_head=16,
        d_ff=64, vocab_size=101, dtype="float32",
    )
    params = tf.init(cfg, jax.random.PRNGKey(0))
    opt_cfg = optim.AdamWConfig(lr=1e-3)
    batch = {"tokens": jnp.asarray(lm_batch(cfg, 8, 32, seed=0, step=0)["tokens"])}
    s1 = optim.init(opt_cfg, params)
    p1, _, m1 = jax.jit(make_train_step(cfg, opt_cfg))(params, s1, batch, jnp.int32(0))
    s2 = optim.init(opt_cfg, params)
    p2, _, m2 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=4))(
        params, s2, batch, jnp.int32(0)
    )
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
