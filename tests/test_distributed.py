"""Distributed matcher (shard_map, 8 simulated machines): exactness vs the
oracle, disjointness of per-shard results, and the OR-allreduce collective.

Multi-device tests run in a subprocess so the main test session keeps a
single CPU device (per the dry-run isolation rule).
"""
import json
import pathlib
import subprocess
import sys

import pytest

# whole-module: multi-device subprocess end-to-end runs
pytestmark = pytest.mark.slow

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
TESTS = str(pathlib.Path(__file__).resolve().parent)

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
sys.path.insert(0, %r)
from helpers import dfs_query, nx_oracle
from repro.graphstore import PartitionedGraph, generators
from repro.core import QueryGraph
from repro.core.dist import DistributedMatcher
from repro.core.collectives import or_allreduce
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

out = {}

# --- OR-allreduce butterfly == gather-reduce ---------------------------
mesh = Mesh(np.array(jax.devices()), ("data",))
rng = np.random.default_rng(0)
words = rng.integers(0, 2**32, (8, 64), dtype=np.uint32)
f = jax.jit(shard_map(
    lambda w: or_allreduce(w[0], "data")[None],
    mesh=mesh, in_specs=(P("data"),), out_specs=P("data"), check_vma=False,
))
got = np.asarray(f(words))
want = np.bitwise_or.reduce(words, axis=0)
out["or_allreduce_ok"] = bool((got == want[None]).all())

# --- distributed == oracle, per-shard disjointness ----------------------
g = generators.rmat(160, 520, 4, seed=3, symmetrize=True)
pg = PartitionedGraph.build(g, 8)
dm = DistributedMatcher(pg, mesh)
rng = np.random.default_rng(5)
checks = []
for _ in range(3):
    q = dfs_query(g, rng, 4)
    if q is None:
        continue
    res = dm.match(q, max_matches=0)
    got = set(map(tuple, res.rows.tolist()))
    want = nx_oracle(g, q)
    checks.append(got == want and res.complete
                  and len(res.rows) == len(got))  # no duplicates in union
out["dist_exact"] = all(checks) and len(checks) >= 2
print(json.dumps(out))
""" % (TESTS,)


@pytest.fixture(scope="module")
def dist_results():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_or_allreduce(dist_results):
    assert dist_results["or_allreduce_ok"]


def test_distributed_matches_oracle_no_dedup(dist_results):
    assert dist_results["dist_exact"]
