"""Resilience layer (repro.runtime.resilience): typed degradation, deadline
and memory-budget guards, the cost-model-backed retry ceiling, and run/stream
stats parity. Chaos-injected fault paths live in tests/test_chaos.py."""
import numpy as np
import pytest

from helpers import dfs_query, nx_oracle
from repro.api import GraphSession
from repro.graphstore import generators
from repro.runtime.resilience import (
    DegradeReason,
    QueryGuard,
    RetryPolicy,
    adaptive_run,
    degraded_empty,
    grow_caps,
    plan_caps_bytes,
    retry_ceiling_bytes,
)


def _graph(n=120, seed=3):
    return generators.rmat(n, 4 * n, 4, seed=seed, symmetrize=True)


# ---------------------------------------------------------------- vocabulary


def test_degrade_reason_values_pinned():
    # these strings are API: serve.py logs them, clients switch on them
    assert DegradeReason.DEADLINE == "deadline"
    assert DegradeReason.BUDGET == "budget"
    assert DegradeReason.OVERFLOW_CEILING == "overflow-ceiling"
    assert DegradeReason.SHARD_FAULT == "shard-fault"
    assert str(DegradeReason.DEADLINE) == "deadline"


# --------------------------------------------------------------------- guard


def test_guard_deadline_fake_clock():
    t = [0.0]
    g = QueryGuard(deadline_s=1.0, clock=lambda: t[0]).start()
    assert g.check() is None
    assert g.remaining_s() == pytest.approx(1.0)
    t[0] = 0.99
    assert g.check() is None
    t[0] = 1.01
    assert g.check() is DegradeReason.DEADLINE
    # start() is idempotent: re-entering keeps the original epoch
    g.start()
    assert g.started_at == 0.0


def test_guard_memory_budget():
    g = QueryGuard(memory_budget_bytes=1000.0).start()
    assert g.check() is None  # no planned bytes, no deadline -> fine
    assert g.check(planned_bytes=999.0) is None
    assert g.check(planned_bytes=1001.0) is DegradeReason.BUDGET


def test_guard_deadline_takes_priority():
    t = [10.0]
    g = QueryGuard(
        deadline_s=1.0, memory_budget_bytes=1.0, clock=lambda: t[0]
    ).start()
    t[0] = 20.0
    assert g.check(planned_bytes=1e9) is DegradeReason.DEADLINE


# -------------------------------------------------------------------- policy


def test_backoff_seeded_deterministic():
    a = RetryPolicy(backoff_s=0.01, seed=7)
    b = RetryPolicy(backoff_s=0.01, seed=7)
    seq_a = [a.backoff(i) for i in range(6)]
    seq_b = [b.backoff(i) for i in range(6)]
    assert seq_a == seq_b
    c = RetryPolicy(backoff_s=0.01, seed=8)
    assert [c.backoff(i) for i in range(6)] != seq_a
    # geometric growth dominates the jitter: attempt i+1 > attempt i
    assert all(y > x for x, y in zip(seq_a, seq_a[1:]))


def test_cost_estimate_monotone_in_caps():
    caps = {"child_cap": 8, "join_rows_cap": 1 << 14, "join_dup_cap": 64}
    est = [plan_caps_bytes(caps)]
    for _ in range(3):
        caps = grow_caps(caps)
        est.append(plan_caps_bytes(caps))
    assert all(e > 0 for e in est)
    assert all(b > a for a, b in zip(est, est[1:]))


def test_retry_ceiling_reads_budgets_json():
    # the checked-in ceiling (analysis/budgets.json "retry" section)
    assert retry_ceiling_bytes() == 16e9
    # missing section falls back conservatively instead of failing open
    assert retry_ceiling_bytes({}) == 4e9
    assert retry_ceiling_bytes({"retry": {"memory_ceiling_bytes": 123.0}}) == 123.0


def test_next_caps_never_exceeds_ceiling():
    # acceptance: adaptive retry never plans caps whose cost-model estimate
    # exceeds the ceiling -- walk escalations until refusal and check each
    caps = {"child_cap": 8, "join_rows_cap": 1 << 14, "join_dup_cap": 64}
    ceiling = plan_caps_bytes(grow_caps(grow_caps(caps))) * 1.01
    policy = RetryPolicy(ceiling_bytes=ceiling)
    accepted = []
    for _ in range(10):
        grown, reason = policy.next_caps(caps)
        if grown is None:
            assert reason is DegradeReason.OVERFLOW_CEILING
            break
        accepted.append(grown)
        caps = grown
    else:
        pytest.fail("next_caps never hit the ceiling")
    assert len(accepted) == 2  # exactly the escalations under the ceiling
    assert all(plan_caps_bytes(c) <= ceiling for c in accepted)


def test_next_caps_guard_budget_wins_over_ceiling():
    caps = {"child_cap": 8, "join_rows_cap": 1 << 14, "join_dup_cap": 64}
    g = QueryGuard(memory_budget_bytes=1.0).start()
    grown, reason = RetryPolicy(ceiling_bytes=float("inf")).next_caps(caps, g)
    assert grown is None and reason is DegradeReason.BUDGET


# --------------------------------------------------------------- retry loop


def _overflowing(n_qnodes=3, backend="local"):
    """A first/escalate pair that never completes, recording escalated caps."""
    from repro.core.result import MatchResult, MatchStats

    seen = []

    def make(caps):
        seen.append(dict(caps) if caps else None)
        return MatchResult(
            rows=np.zeros((0, n_qnodes), np.int64),
            n_matches=0,
            complete=False,
            stats=MatchStats(backend=backend),
        )

    return (lambda: make(None)), (lambda caps: make(caps)), seen


def test_adaptive_run_stops_at_ceiling_with_typed_reason():
    first, escalate, seen = _overflowing()
    caps = {"child_cap": 8, "join_rows_cap": 1 << 14, "join_dup_cap": 64}
    ceiling = plan_caps_bytes(grow_caps(caps)) * 1.01
    res = adaptive_run(
        first,
        escalate,
        caps,
        n_qnodes=3,
        backend="local",
        policy=RetryPolicy(ceiling_bytes=ceiling),
    )
    assert not res.complete
    assert res.stats.degrade_reason == "overflow-ceiling"
    assert res.stats.retries == 1
    # every escalated plan's estimate fit under the ceiling (acceptance)
    escalated = [c for c in seen if c is not None]
    assert len(escalated) == 1
    assert all(plan_caps_bytes(c) <= ceiling for c in escalated)
    assert res.stats.final_caps == {
        k: escalated[-1][k]
        for k in ("child_cap", "join_rows_cap", "join_dup_cap")
    }


def test_adaptive_run_exhausts_retry_budget():
    first, escalate, seen = _overflowing()
    res = adaptive_run(
        first,
        escalate,
        {"child_cap": 8, "join_rows_cap": 1 << 14, "join_dup_cap": 64},
        n_qnodes=3,
        backend="local",
        policy=RetryPolicy(max_retries=2, ceiling_bytes=float("inf")),
    )
    assert res.stats.degrade_reason == "overflow-ceiling"
    assert res.stats.retries == 2
    assert len(seen) == 3  # first + two escalations


def test_adaptive_run_respects_existing_degrade_reason():
    # a shard fault is not a capacity problem: no escalation may fire
    from repro.core.result import MatchResult, MatchStats

    def first():
        stats = MatchStats(backend="sharded")
        stats.degrade_reason = DegradeReason.SHARD_FAULT.value
        return MatchResult(
            rows=np.zeros((0, 3), np.int64),
            n_matches=0,
            complete=False,
            stats=stats,
        )

    def escalate(caps):
        pytest.fail("shard-fault result must not trigger cap escalation")

    res = adaptive_run(
        first, escalate, {"child_cap": 8}, n_qnodes=3, backend="sharded"
    )
    assert res.stats.degrade_reason == "shard-fault"
    assert res.stats.retries == 0


def test_degraded_empty_shape():
    res = degraded_empty(5, "local", DegradeReason.BUDGET)
    assert res.rows.shape == (0, 5)
    assert not res.complete
    assert res.stats.degrade_reason == "budget"
    assert res.degrade_reason == "budget"  # MatchResult property delegates


# --------------------------------------------------------- facade end-to-end


def test_pre_expired_deadline_returns_degraded_empty():
    g = _graph()
    with GraphSession.open(g, backend="local") as s:
        rng = np.random.default_rng(0)
        q = dfs_query(g, rng, 3)
        assert q is not None
        res = s.run(q, deadline_s=0.0)
    assert not res.complete
    assert res.stats.degrade_reason == "deadline"
    assert res.n_matches == 0


def test_memory_budget_refused_at_admission():
    g = _graph()
    with GraphSession.open(g, backend="local") as s:
        rng = np.random.default_rng(0)
        q = dfs_query(g, rng, 3)
        assert q is not None
        res = s.run(q, memory_budget_bytes=1000.0)
    assert not res.complete
    assert res.stats.degrade_reason == "budget"
    assert res.n_matches == 0


def test_clean_run_unaffected_by_generous_guard():
    g = _graph()
    with GraphSession.open(g, backend="local") as s:
        rng = np.random.default_rng(1)
        q = dfs_query(g, rng, 3)
        assert q is not None
        res = s.run(q, deadline_s=300.0, memory_budget_bytes=64e9)
        assert res.complete
        assert res.stats.degrade_reason is None
        assert set(map(tuple, res.rows.tolist())) == nx_oracle(g, q)
        # per-stage timings were recorded at the host boundaries
        assert {"explore", "join", "materialize"} <= set(
            res.stats.stage_times
        )
        assert all(t >= 0 for t in res.stats.stage_times.values())


def test_run_stream_stats_parity():
    # satellite: retries + final caps surface identically through run() and
    # stream() pages (adaptive=False -- streaming never escalates, so the
    # comparable run is the first-K one)
    g = _graph(seed=5)
    with GraphSession.open(g, backend="local") as s:
        rng = np.random.default_rng(2)
        q = dfs_query(g, rng, 3)
        assert q is not None
        res = s.run(q, adaptive=False)
        pages = list(s.stream(q, page_size=64))
        assert pages, "stream produced no pages"
        st = pages[0].stats
        assert st is not None
        assert all(p.stats is st for p in pages)  # one shared stats object
        assert st.retries == res.stats.retries == 0
        assert st.final_caps == res.stats.final_caps
        assert {"explore", "join"} <= set(st.stage_times)
        got = [r for p in pages for r in map(tuple, p.rows.tolist())]
        assert set(got) == set(map(tuple, res.rows.tolist()))


def test_stream_deadline_ends_with_degraded_page():
    g = _graph()
    with GraphSession.open(g, backend="local") as s:
        rng = np.random.default_rng(3)
        q = dfs_query(g, rng, 3)
        assert q is not None
        t = [0.0]
        guard = QueryGuard(deadline_s=1.0, clock=lambda: t[0])
        calls0 = s.engine.join_block_calls
        # caps big enough that the stream is complete (streaming never
        # escalates); page_size=1 so the first page yields after the first
        # non-empty block, leaving the rest pending behind the guard
        cq = s.compile(q, child_cap=32, join_rows_cap=1 << 18)
        stream = cq.stream(page_size=1, block_rows=4, guard=guard)
        first = next(stream)
        t[0] = 2.0  # expire mid-stream
        rest = list(stream)
        assert rest, "expired guard must surface a final degraded page"
        last = rest[-1]
        assert not last.complete
        assert last.stats.degrade_reason == "deadline"
        # pages already delivered stay valid rows of the true result
        oracle = nx_oracle(g, q)
        assert set(map(tuple, first.rows.tolist())) <= oracle
        # remaining blocks were never joined: strictly fewer join calls
        # than a full consumption of the same stream
        partial_calls = s.engine.join_block_calls - calls0
        full = list(cq.stream(page_size=16, block_rows=4))
        full_calls = s.engine.join_block_calls - calls0 - partial_calls
        assert sum(p.rows.shape[0] for p in full) == len(oracle)
        assert partial_calls < full_calls
