"""Kernel backend layer: registry semantics, pallas(interpret) ≡ jnp parity
for every registry op, the bitset_lookup out-of-range regression, and
end-to-end jnp vs pallas-interpret equivalence through the facade."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.backend import (
    Kernels,
    available_backends,
    get_kernels,
    n_words,
    resolve_kernels,
)

JNP = get_kernels("jnp")
PAL = get_kernels("pallas-interpret")


# ----------------------------------------------------------------- registry
def test_registry_names_and_resolution():
    assert {"jnp", "pallas", "pallas-interpret"} <= set(available_backends())
    assert get_kernels("jnp") is get_kernels("jnp")  # singletons
    assert resolve_kernels(JNP) is JNP               # instances pass through
    assert resolve_kernels("jnp").name == "jnp"
    assert resolve_kernels(None).name in ("jnp", "pallas")  # auto
    with pytest.raises(ValueError, match="unknown kernel backend"):
        get_kernels("no-such-backend")


def test_labels_reexports_are_registry_ops():
    # graphstore must hold no bitset logic of its own — its names must BE
    # the canonical reference ops (guards against silent re-divergence)
    from repro.graphstore import labels
    from repro.kernels.bitset import ref

    assert labels.jnp_bitset_test is ref.lookup_reference
    assert labels.jnp_bitset_build is ref.build_reference
    assert labels.pack_bitset is ref.pack_bitset
    assert labels.unpack_bitset is ref.unpack_bitset


# ---------------------------------------------------------------- op parity
def _rand_words(rng, W, rows=None):
    shape = (W,) if rows is None else (rows, W)
    return jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))


def test_parity_bitset_pack_unpack():
    rng = np.random.default_rng(0)
    words = _rand_words(rng, 128)
    bits_j = JNP.bitset_unpack(words)
    bits_p = PAL.bitset_unpack(words)
    assert (np.asarray(bits_j) == np.asarray(bits_p)).all()
    assert (
        np.asarray(JNP.bitset_pack(bits_j)) == np.asarray(PAL.bitset_pack(bits_j))
    ).all()
    assert (np.asarray(PAL.bitset_pack(bits_p)) == np.asarray(words)).all()


def test_parity_bitset_lookup_and_adversarial_ids():
    rng = np.random.default_rng(1)
    W = 64
    words = _rand_words(rng, W)
    n_bits = W * 32
    # in-range, boundary, negative, far out-of-range, INT_MIN/MAX
    ids = jnp.asarray(
        np.concatenate(
            [
                rng.integers(0, n_bits, 256),
                [0, n_bits - 1, n_bits, n_bits + 31, -1, -32, -(2**31), 2**31 - 1],
            ]
        ),
        jnp.int32,
    )
    got_j = np.asarray(JNP.bitset_lookup(words, ids))
    got_p = np.asarray(PAL.bitset_lookup(words, ids))
    assert (got_j == got_p).all()
    # regression: every out-of-range id is False, it aliases no real bit
    oor = (np.asarray(ids) < 0) | (np.asarray(ids) >= n_bits)
    assert not got_j[oor].any()
    assert not got_p[oor].any()
    # in-range ids agree with the host-side reference
    from repro.kernels.bitset.ref import bitset_test_np

    ids_in = np.asarray(ids)[~oor]
    assert (got_j[~oor] == bitset_test_np(np.asarray(words), ids_in)).all()


def test_parity_bitset_build():
    rng = np.random.default_rng(2)
    nwords = 32
    n_bits = nwords * 32
    ids = jnp.asarray(rng.integers(0, n_bits, 500), jnp.int32)
    valid = jnp.asarray(rng.random(500) < 0.7)
    a = np.asarray(JNP.bitset_build(ids, valid, nwords))
    b = np.asarray(PAL.bitset_build(ids, valid, nwords))
    assert (a == b).all()
    # semantic check: exactly the valid ids' bits are set
    want = np.zeros(n_bits, bool)
    want[np.asarray(ids)[np.asarray(valid)]] = True
    got = np.asarray(JNP.bitset_unpack(jnp.asarray(a)))
    assert (got == want).all()


def test_parity_candidate_filter():
    rng = np.random.default_rng(3)
    W, E = 64, 512
    words = _rand_words(rng, W)
    ids = jnp.asarray(rng.integers(-8, W * 32 + 8, E), jnp.int32)  # some OOR
    labs = jnp.asarray(rng.integers(0, 4, E), jnp.int32)
    rok = jnp.asarray(rng.random(E) < 0.7)
    a = np.asarray(JNP.candidate_filter(words, ids, labs, rok, 2))
    b = np.asarray(PAL.candidate_filter(words, ids, labs, rok, 2))
    assert (a == b).all()


def _expand_inputs(rng, cap=41, E=160, n_total=300, k=2, child_cap=3,
                   n_labels=4):
    src = np.sort(rng.integers(0, cap, E)).astype(np.int32)
    # (cap+2,) CSR bounds over the edge arrays; indptr[cap+1] == E
    indptr = np.searchsorted(src, np.arange(cap + 2)).astype(np.int32)
    dst = rng.integers(0, n_total, E).astype(np.int32)
    labs = rng.integers(0, n_labels, E).astype(np.int32)
    rok = rng.random(E) < 0.8
    W = n_words(n_total + 1)
    words = rng.integers(0, 2**32, (k, W), dtype=np.uint32)
    args = tuple(jnp.asarray(x) for x in (words, dst, labs, indptr, rok))
    kw = dict(
        child_labels=(1, 2),
        child_bound=(True, False),
        child_cap=child_cap,
        cap=cap,
        n_total=n_total,
    )
    return args, kw


def _expand_oracle_np(args, kw):
    """Host-side reference: per root r, the surviving dsts of the edges in
    [indptr[r], indptr[r+1]) in edge order; exact counts."""
    from repro.kernels.bitset.ref import bitset_test_np

    words, dst, labs, indptr, rok = (np.asarray(a) for a in args)
    k = len(kw["child_labels"])
    cap, C, n_total = kw["cap"], kw["child_cap"], kw["n_total"]
    cand = np.full((k, cap + 1, C), n_total, np.int32)
    cnt = np.zeros((k, cap), np.int32)
    for c in range(k):
        m = rok & (labs == kw["child_labels"][c])
        if kw["child_bound"][c]:
            m &= bitset_test_np(words[c], dst)
        for r in range(cap):
            sel = dst[indptr[r]:indptr[r + 1]][m[indptr[r]:indptr[r + 1]]]
            cnt[c, r] = len(sel)
            cand[c, r, : min(len(sel), C)] = sel[:C]
    return cand, cnt


def _assert_expand_parity(args, kw):
    cj, nj = JNP.stwig_expand(*args, **kw)
    cp, np_ = PAL.stwig_expand(*args, **kw)
    assert (np.asarray(nj) == np.asarray(np_)).all()
    assert (np.asarray(cj) == np.asarray(cp)).all()
    co, no = _expand_oracle_np(args, kw)
    assert (np.asarray(nj) == no).all()
    assert (np.asarray(cj) == co).all()


def test_parity_stwig_expand():
    for seed in range(3):
        args, kw = _expand_inputs(np.random.default_rng(seed))
        _assert_expand_parity(args, kw)


def test_stwig_expand_counts_grow_past_child_cap():
    """cnt is EXACT even when a root has more survivors than child_cap —
    the overflow signal the engine's adaptive retry keys on."""
    # one root owns every edge, labels/bitsets fully permissive
    E, cap, n_total = 64, 5, 100
    indptr = np.zeros(cap + 2, np.int32)
    indptr[1:] = E  # root 0 owns [0, E)
    dst = np.arange(E, dtype=np.int32)
    labs = np.full(E, 1, np.int32)
    rok = np.ones(E, bool)
    words = np.full((2, n_words(n_total + 1)), 0xFFFFFFFF, np.uint32)
    args = tuple(jnp.asarray(x) for x in (words, dst, labs, indptr, rok))
    kw = dict(child_labels=(1, 1), child_bound=(True, False), child_cap=3,
              cap=cap, n_total=n_total)
    for kern in (JNP, PAL):
        cand, cnt = kern.stwig_expand(*args, **kw)
        assert (np.asarray(cnt)[:, 0] == E).all()      # exact, not clamped
        assert (np.asarray(cnt)[:, 1:] == 0).all()
        assert (np.asarray(cand)[:, 0] == [0, 1, 2]).all()  # first C, in order
        assert (np.asarray(cand)[:, 1:] == n_total).all()
    _assert_expand_parity(args, kw)


def test_stwig_expand_segment_straddles_tiles():
    """A root whose surviving edges straddle an edge-tile boundary must
    compact across the carry (pallas tiles at be; force multiple tiles)."""
    from repro.kernels.stwig_expand.stwig_expand import stwig_expand

    rng = np.random.default_rng(11)
    cap, n_total, be = 3, 400, 16
    E = 3 * be  # three tiles
    # root 1's segment covers the first two tile boundaries
    src = np.concatenate([
        np.zeros(4, np.int32), np.full(E - 8, 1, np.int32),
        np.full(4, 2, np.int32),
    ])
    indptr = np.searchsorted(src, np.arange(cap + 2)).astype(np.int32)
    dst = rng.integers(0, n_total, E).astype(np.int32)
    labs = rng.integers(0, 2, E).astype(np.int32)
    rok = np.ones(E, bool)
    words = rng.integers(0, 2**32, (2, n_words(n_total + 1)), dtype=np.uint32)
    args = tuple(jnp.asarray(x) for x in (words, dst, labs, indptr, rok))
    kw = dict(child_labels=(1, 0), child_bound=(True, False), child_cap=6,
              cap=cap, n_total=n_total)
    cj, nj = JNP.stwig_expand(*args, **kw)
    cp, np_ = stwig_expand(*args, **kw, be=be, interpret=True)
    assert (np.asarray(nj) == np.asarray(np_)).all()
    assert (np.asarray(cj) == np.asarray(cp)).all()
    co, no = _expand_oracle_np(args, kw)
    assert (np.asarray(nj) == no).all() and (np.asarray(cj) == co).all()


@pytest.mark.parametrize("E", [128, 160, 127])  # pow2, non-pow2, prime
def test_parity_stwig_expand_edge_lengths(E):
    """Pinned regression for the degenerate tile fallback: the old kernel
    halved the tile size until it divided E — be=1 (an E-step grid) for
    prime E. The padded-tile kernel must stay exact for any E, including
    a tile size that does NOT divide E (forced be=32)."""
    from repro.kernels.stwig_expand.stwig_expand import stwig_expand

    args, kw = _expand_inputs(np.random.default_rng(17), E=E)
    _assert_expand_parity(args, kw)
    cj, nj = JNP.stwig_expand(*args, **kw)
    cp, np_ = stwig_expand(*args, **kw, be=32, interpret=True)
    assert (np.asarray(nj) == np.asarray(np_)).all()
    assert (np.asarray(cj) == np.asarray(cp)).all()


def test_parity_hash_join_probe():
    rng = np.random.default_rng(5)
    capA, capB, nk, dup = 128, 96, 2, 8
    ka = np.sort(rng.integers(0, 40, capA)).astype(np.uint32)
    akeys = rng.integers(0, 9, (capA, nk)).astype(np.int32)
    avalid = rng.random(capA) < 0.8
    kb = rng.integers(0, 40, capB).astype(np.uint32)
    bkeys = rng.integers(0, 9, (capB, nk)).astype(np.int32)
    bvalid = rng.random(capB) < 0.8
    args = tuple(
        jnp.asarray(x) for x in (ka, akeys, avalid, kb, bkeys, bvalid)
    )
    hj, ij = JNP.hash_join_probe(*args, dup_cap=dup)
    hp, ip = PAL.hash_join_probe(*args, dup_cap=dup)
    assert (np.asarray(hj) == np.asarray(hp)).all()
    assert (np.asarray(ij) == np.asarray(ip)).all()


def test_parity_hash_join_probe_power_of_two_run_start():
    """Regression: with power-of-two cap_a the in-kernel binary search used
    to run one step short, landing one row before the true run start and
    silently dropping the last duplicate of a full-dup_cap run."""
    ka = jnp.asarray([0, 5, 5, 5, 5, 5, 5, 9], jnp.uint32)  # cap_a = 8 = 2**3
    akeys = jnp.arange(8, dtype=jnp.int32)[:, None] * 0 + 5
    avalid = jnp.ones(8, bool)
    kb = jnp.asarray([5], jnp.uint32)
    bkeys = jnp.asarray([[5]], jnp.int32)
    bvalid = jnp.ones(1, bool)
    args = (ka, akeys, avalid, kb, bkeys, bvalid)
    hj, ij = JNP.hash_join_probe(*args, dup_cap=6)
    hp, ip = PAL.hash_join_probe(*args, dup_cap=6)
    assert (np.asarray(hj) == np.asarray(hp)).all()
    assert (np.asarray(ij) == np.asarray(ip)).all()
    # the window must cover the whole run: rows 1..6 all hit
    assert np.asarray(hp).sum() == 6 and np.asarray(ip)[0, 0] == 1


# --------------------------------------------------------------- end to end
def _row_set(res):
    return set(map(tuple, res.rows.tolist()))


def test_end_to_end_local_jnp_vs_pallas_interpret():
    """Acceptance: identical MatchResult rows for the same graph+query under
    kernels="jnp" and kernels="pallas-interpret" (local backend)."""
    from repro.api import GraphSession
    from repro.graphstore import generators
    from repro.workloads import dfs_query, path_query

    g = generators.rmat(200, 700, 5, seed=4, symmetrize=True)
    rng = np.random.default_rng(7)
    queries = []
    while len(queries) < 2:
        q = dfs_query(g, rng, 4) if len(queries) == 0 else path_query(g, rng, 4)
        if q is not None:
            queries.append(q)

    s_jnp = GraphSession.open(g, backend="local", kernels="jnp")
    s_pal = GraphSession.open(g, backend="local", kernels="pallas-interpret")
    assert s_jnp.kernels.name == "jnp"
    assert s_pal.kernels.name == "pallas-interpret"
    for q in queries:
        r_jnp = s_jnp.run(q, max_matches=0)
        r_pal = s_pal.run(q, max_matches=0)
        assert r_jnp.complete == r_pal.complete
        assert _row_set(r_jnp) == _row_set(r_pal)


SHARDED_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys
import numpy as np
sys.path.insert(0, %r)
from helpers import path_query
from repro.api import GraphSession
from repro.graphstore import PartitionedGraph, generators

g = generators.rmat(120, 420, 4, seed=6, symmetrize=True)
pg = PartitionedGraph.build(g, 4)
rng = np.random.default_rng(3)
q = None
while q is None:
    q = path_query(g, rng, 3)

rows = {}
for kern in ("jnp", "pallas-interpret"):
    s = GraphSession.open(pg, backend="sharded", kernels=kern)
    res = s.run(q, max_matches=0)
    rows[kern] = sorted(map(tuple, res.rows.tolist()))
print(json.dumps({"equal": rows["jnp"] == rows["pallas-interpret"],
                  "n": len(rows["jnp"])}))
"""


@pytest.mark.slow
def test_end_to_end_sharded_jnp_vs_pallas_interpret():
    """Acceptance (sharded half): identical rows under both kernel backends
    through shard_map. Subprocess so the main session keeps one device."""
    import json
    import pathlib
    import subprocess
    import sys

    here = pathlib.Path(__file__).resolve().parent
    src = str(here.parent / "src")
    proc = subprocess.run(
        [sys.executable, "-c", SHARDED_PARITY_SCRIPT % str(here)],
        capture_output=True,
        text=True,
        timeout=1200,
        env={
            **__import__("os").environ,
            "PYTHONPATH": src,
            "JAX_PLATFORMS": "cpu",
        },
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["equal"], out
    assert out["n"] > 0, "parity on an empty result set proves nothing"


def test_kernel_switch_keys_cache_no_poisoning():
    """One session compares backends: switching kernels mid-session builds
    new executables under new keys; switching back reuses the old ones."""
    from repro.api import GraphSession
    from repro.graphstore import generators
    from repro.workloads import path_query

    g = generators.rmat(150, 500, 4, seed=9, symmetrize=True)
    rng = np.random.default_rng(0)
    q = None
    while q is None:
        q = path_query(g, rng, 3)

    s = GraphSession.open(g, backend="local", kernels="jnp")
    base = _row_set(s.run(q, max_matches=0))
    misses_after_jnp = s.cache.misses

    s.set_kernels("pallas-interpret")
    assert s.compile(q).kernels == "pallas-interpret"
    assert _row_set(s.run(q, max_matches=0)) == base
    assert s.cache.misses > misses_after_jnp  # new executables, new keys

    s.set_kernels("jnp")
    misses_before_back = s.cache.misses
    assert _row_set(s.run(q, max_matches=0)) == base
    assert s.cache.misses == misses_before_back  # fully reused, no poisoning
