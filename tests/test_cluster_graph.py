"""Cluster graph + load sets (paper §5.3, Theorems 3-5)."""
import numpy as np

from repro.core import QueryGraph, SubgraphMatcher, make_plan
from repro.graphstore import ClusterGraphIndex, PartitionedGraph, generators


def test_theorem3_distance_bound():
    """D_C(shard(u), shard(v)) <= D_Gq(u, v) for all u, v."""
    g = generators.ring_of_cliques(6, 8, 3, seed=1)
    pg = PartitionedGraph.build(g, 6, mode="range")
    cgi = ClusterGraphIndex.build(pg)
    all_pairs = [(a, b) for a in range(3) for b in range(3)]
    C = cgi.cluster_adjacency(all_pairs)
    D = ClusterGraphIndex.bfs_distances(C)
    # BFS over the data graph from a few sources
    rng = np.random.default_rng(0)
    for src in rng.choice(g.n_nodes, 5, replace=False):
        dist = {int(src): 0}
        frontier = [int(src)]
        while frontier:
            nxt = []
            for v in frontier:
                for u in g.neighbors(v):
                    u = int(u)
                    if u not in dist:
                        dist[u] = dist[v] + 1
                        nxt.append(u)
            frontier = nxt
        s_src = int(pg.old_to_new[src] // pg.cap)
        for v, d_uv in dist.items():
            s_v = int(pg.old_to_new[v] // pg.cap)
            assert D[s_src, s_v] <= d_uv


def test_ring_cluster_graph_is_sparse():
    g = generators.ring_of_cliques(8, 10, 4, seed=0)
    pg = PartitionedGraph.build(g, 8, mode="range")
    cgi = ClusterGraphIndex.build(pg)
    C = cgi.cluster_adjacency([(a, b) for a in range(4) for b in range(4)])
    # range partition of a ring of cliques → (near-)ring cluster graph
    assert C.sum() < 8 * 8, "cluster graph must not be complete"
    D = ClusterGraphIndex.bfs_distances(C)
    assert D.max() >= 2, "load sets can exclude far shards"


def test_load_sets_head_is_local():
    g = generators.ring_of_cliques(8, 10, 4, seed=0)
    pg = PartitionedGraph.build(g, 8, mode="range")
    cgi = ClusterGraphIndex.build(pg)
    q = QueryGraph.build([0, 1, 2], [(0, 1), (1, 2)])
    plan = make_plan(q, pg.freq)
    load = cgi.load_sets(q.label_pairs(), plan.head_dists)
    head_row = load[plan.head]
    assert (head_row == np.eye(8, dtype=bool)).all(), "head STwig loads only itself"
    # monotone: larger distance → superset load set
    for t, d in enumerate(plan.head_dists):
        if d > 0:
            assert load[t].sum() >= head_row.sum()
