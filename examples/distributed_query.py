"""Distributed matching across 8 simulated machines (paper §4.3/§5.3):
head-STwig locality, load sets from the cluster graph, disjoint unions —
all behind the same `GraphSession` facade as the local engine.

    PYTHONPATH=src python examples/distributed_query.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from repro.api import GraphSession  # noqa: E402
from repro.core import QueryGraph  # noqa: E402
from repro.graphstore import PartitionedGraph, generators  # noqa: E402


def main() -> None:
    # ring-of-cliques + range partition → a genuinely sparse cluster graph,
    # so load sets exclude far machines (Theorem 4 with teeth)
    g = generators.ring_of_cliques(n_cliques=8, clique_size=12, n_labels=4, seed=0)
    pg = PartitionedGraph.build(g, 8, mode="range")
    session = GraphSession.open(pg)  # backend="auto" → sharded over 8 devices
    print(session)

    q = QueryGraph.build(labels=[0, 1, 2, 3], edges=[(0, 1), (1, 2), (2, 3), (0, 2)])
    compiled = session.compile(q, max_matches=0)
    plan = compiled.plan
    load = session.engine.cgi.load_sets(q.label_pairs(), plan.head_dists)
    print("head STwig:", plan.head, "head distances:", plan.head_dists)
    for t in range(load.shape[0]):
        sizes = load[t].sum(axis=1)
        print(
            f"  STwig {t}: load-set sizes per machine = {sizes.tolist()}"
            + ("   (head: local only)" if t == plan.head else "")
        )

    res = compiled.run()
    print(f"\n{res.n_matches} matches across {res.stats.n_shards} machines "
          f"(complete={res.complete}); no deduplication was performed.")
    rows = {tuple(r) for r in res.rows.tolist()}
    assert len(rows) == res.n_matches, "disjointness guarantee violated!"
    print("disjointness check passed")


if __name__ == "__main__":
    main()
