"""Train a small LM with the full production loop: AdamW + cosine schedule,
microbatched gradient accumulation, async checkpoints, restart-on-failure.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import Checkpointer
from repro.configs.base import LMConfig
from repro.data import lm_batch
from repro.models import transformer as tf
from repro.runtime import SimulatedPreemption, TrainSupervisor
from repro.train import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    ap.add_argument("--inject-failure", action="store_true")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt, ignore_errors=True)

    cfg = LMConfig(
        name="lm-demo", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
        d_head=32, d_ff=1024, vocab_size=4096, dtype="float32",
    )
    params = tf.init(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params")

    opt_cfg = optim.AdamWConfig(lr=1e-3)
    opt_state = optim.init(opt_cfg, params)
    raw = jax.jit(make_train_step(cfg, opt_cfg, total_steps=args.steps, warmup=20))

    def step_fn(state, batch, step):
        p, s = state
        p, s, m = raw(p, s, {"tokens": jnp.asarray(batch["tokens"])}, np.int32(step))
        return (p, s), m

    def batch_fn(step):
        return lm_batch(cfg, args.batch, args.seq, seed=0, step=step)

    sup = TrainSupervisor(
        Checkpointer(args.ckpt), ckpt_every=50,
        fail_at={args.steps // 2: lambda: SimulatedPreemption("injected")}
        if args.inject_failure
        else {},
    )
    try:
        state, hist = sup.run(
            state=(params, opt_state), step_fn=step_fn, batch_fn=batch_fn,
            n_steps=args.steps,
        )
    except SimulatedPreemption:
        print("!! preempted — restarting from latest checkpoint")
        state, hist = sup.run(
            state=(params, opt_state), step_fn=step_fn, batch_fn=batch_fn,
            n_steps=args.steps,
        )
    for h in hist[:: max(1, len(hist) // 8)]:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  {h['dt']*1e3:5.0f} ms")
    print(f"final loss {hist[-1]['loss']:.4f} (started ~{np.log(4096):.2f} = ln V)")
    assert hist[-1]["loss"] < np.log(4096), "no learning happened?"


if __name__ == "__main__":
    main()
