"""End-to-end driver (the paper's kind: online subgraph-query serving).

Builds a patents-shaped graph, then serves a mixed workload of DFS + random
queries with the paper's pipeline semantics (first 1024 matches per query),
reporting throughput and latency percentiles.

    PYTHONPATH=src python examples/serve_queries.py [--n-queries 40]
"""
import argparse
import time

import numpy as np

from repro.core import SubgraphMatcher, QueryGraph
from repro.graphstore import PartitionedGraph, generators


def dfs_query(g, rng, nq):
    start = int(rng.integers(g.n_nodes))
    nodes, edges, seen = [start], [], {start}
    stack = [start]
    while stack and len(nodes) < nq:
        v = stack.pop()
        for u in g.neighbors(v):
            u = int(u)
            if u not in seen and len(nodes) < nq:
                seen.add(u)
                nodes.append(u)
                edges.append((v, u))
                stack.append(u)
    if len(nodes) < 2:
        return None
    remap = {v: i for i, v in enumerate(nodes)}
    return QueryGraph.build(
        [int(g.labels[v]) for v in nodes],
        [(remap[a], remap[b]) for a, b in edges],
    )


def random_query(nq, ne, n_labels, rng):
    edges = [(int(rng.integers(i)), i) for i in range(1, nq)]
    seen = {(min(a, b), max(a, b)) for a, b in edges}
    while len(edges) < ne:
        a, b = rng.integers(nq, size=2)
        key = (min(a, b), max(a, b))
        if a != b and key not in seen:
            seen.add(key)
            edges.append((int(a), int(b)))
        else:
            break
    return QueryGraph.build(rng.integers(0, n_labels, nq).astype(int).tolist(), edges)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-queries", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=100_000)
    ap.add_argument("--degree", type=int, default=16)
    ap.add_argument("--labels", type=int, default=64)
    args = ap.parse_args()

    print(f"loading graph: {args.nodes} nodes, deg {args.degree} ...")
    t0 = time.perf_counter()
    g = generators.rmat(args.nodes, args.degree * args.nodes, args.labels, seed=0)
    pg = PartitionedGraph.build(g, 1)
    print(f"loaded in {time.perf_counter()-t0:.1f}s ({g.n_edges} edges)")
    matcher = SubgraphMatcher(pg)

    rng = np.random.default_rng(0)
    workload = []
    for i in range(args.n_queries):
        q = (
            dfs_query(g, rng, int(rng.integers(4, 8)))
            if i % 2 == 0
            else random_query(int(rng.integers(4, 8)), 8, args.labels, rng)
        )
        if q is not None:
            workload.append(q)

    lat, matched = [], 0
    t0 = time.perf_counter()
    for q in workload:
        s = time.perf_counter()
        res = matcher.match(q, max_matches=1024, adaptive=False)
        lat.append(time.perf_counter() - s)
        matched += res.n_matches
    wall = time.perf_counter() - t0

    lat_ms = np.sort(np.asarray(lat)) * 1e3
    print(f"\nserved {len(workload)} queries in {wall:.1f}s "
          f"({len(workload)/wall:.2f} qps, {matched} total matches)")
    print(f"latency p50={lat_ms[len(lat)//2]:.0f}ms "
          f"p90={lat_ms[int(len(lat)*0.9)]:.0f}ms p99={lat_ms[-1]:.0f}ms")
    print("(first-query latencies include jit compiles; steady-state "
          "queries reuse the plan-spec compile cache)")


if __name__ == "__main__":
    main()
