"""End-to-end driver (the paper's kind: online subgraph-query serving).

Builds a patents-shaped graph, then serves a mixed workload of DFS + random
queries through the continuous-batching `QueryServer` (`repro.api.serve`):
up to ``--max-inflight`` queries are in flight at once, their block-join
quanta interleaved on the one device, each bounded by a first-K budget
(first 1024 matches) and an optional per-query deadline. Queries with
identical plan shapes share jitted executables via the session cache —
no serving loop is constructed by hand here.

    PYTHONPATH=src python examples/serve_queries.py [--n-queries 40]
"""
import argparse
import time

import numpy as np

from repro.api import GraphSession, summarize_outcomes
from repro.graphstore import generators
from repro.workloads import mixed_workload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-queries", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=100_000)
    ap.add_argument("--degree", type=int, default=16)
    ap.add_argument("--labels", type=int, default=64)
    ap.add_argument("--max-inflight", type=int, default=8)
    ap.add_argument("--deadline-ms", type=float, default=0.0)
    args = ap.parse_args()

    print(f"loading graph: {args.nodes} nodes, deg {args.degree} ...")
    t0 = time.perf_counter()
    g = generators.rmat(args.nodes, args.degree * args.nodes, args.labels, seed=0)
    session = GraphSession.open(g, backend="local")
    print(f"loaded in {time.perf_counter()-t0:.1f}s ({g.n_edges} edges)")

    rng = np.random.default_rng(0)
    workload = mixed_workload(g, args.n_queries, n_labels=args.labels, rng=rng)

    server = session.serve(
        max_inflight=args.max_inflight,
        max_matches=1024,
        deadline_s=args.deadline_ms / 1e3 if args.deadline_ms else None,
    )
    t0 = time.perf_counter()
    outcomes = server.serve(workload)
    wall = time.perf_counter() - t0

    s = summarize_outcomes(outcomes)
    ttfp_ms = np.sort([o.ttfp_s * 1e3 for o in outcomes if o.ttfp_s is not None])
    print(f"\n{s['served']} served / {s['partial']} partial / "
          f"{s['failed']} failed in {wall:.1f}s "
          f"({len(workload)/wall:.2f} qps, {s['n_matches']} total matches)")
    if len(ttfp_ms):
        print(f"time-to-first-page p50={ttfp_ms[len(ttfp_ms)//2]:.0f}ms "
              f"p90={ttfp_ms[int(len(ttfp_ms)*0.9)]:.0f}ms "
              f"p99={ttfp_ms[min(len(ttfp_ms)-1, int(len(ttfp_ms)*0.99))]:.0f}ms")
    print(f"scheduler: {server.stats.join_quanta} block-join quanta across "
          f"{len(server.stats.buckets)} shape buckets "
          f"({server.stats.warm_admissions} warm admissions, "
          f"{server.stats.global_degradations} global degradations)")
    print(f"executable cache: {session.cache.hits} hits, "
          f"{session.cache.misses} misses over the workload")
    print("(first-admitted queries pay the jit compiles; bucket-mates and "
          "steady-state queries reuse the session's executable cache)")


if __name__ == "__main__":
    main()
