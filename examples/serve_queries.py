"""End-to-end driver (the paper's kind: online subgraph-query serving).

Builds a patents-shaped graph, then serves a mixed workload of DFS + random
queries through the `GraphSession` facade with the paper's pipeline
semantics (first 1024 matches per query), reporting throughput and latency
percentiles. `run_batch` amortizes compilation across the workload: queries
with identical STwig specs share jitted executables via the session cache.

    PYTHONPATH=src python examples/serve_queries.py [--n-queries 40]
"""
import argparse
import time

import numpy as np

from repro.api import GraphSession
from repro.graphstore import generators
from repro.workloads import mixed_workload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-queries", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=100_000)
    ap.add_argument("--degree", type=int, default=16)
    ap.add_argument("--labels", type=int, default=64)
    args = ap.parse_args()

    print(f"loading graph: {args.nodes} nodes, deg {args.degree} ...")
    t0 = time.perf_counter()
    g = generators.rmat(args.nodes, args.degree * args.nodes, args.labels, seed=0)
    session = GraphSession.open(g, backend="local")
    print(f"loaded in {time.perf_counter()-t0:.1f}s ({g.n_edges} edges)")

    rng = np.random.default_rng(0)
    workload = mixed_workload(g, args.n_queries, n_labels=args.labels, rng=rng)

    lat, matched = [], 0
    t0 = time.perf_counter()
    for q in workload:
        s = time.perf_counter()
        res = session.run(q, max_matches=1024, adaptive=False)
        lat.append(time.perf_counter() - s)
        matched += res.n_matches
    wall = time.perf_counter() - t0

    lat_ms = np.sort(np.asarray(lat)) * 1e3
    print(f"\nserved {len(workload)} queries in {wall:.1f}s "
          f"({len(workload)/wall:.2f} qps, {matched} total matches)")
    print(f"latency p50={lat_ms[len(lat)//2]:.0f}ms "
          f"p90={lat_ms[int(len(lat)*0.9)]:.0f}ms p99={lat_ms[-1]:.0f}ms")
    print(f"executable cache: {session.cache.hits} hits, "
          f"{session.cache.misses} misses over the workload")
    print("(first-query latencies include jit compiles; steady-state "
          "queries reuse the session's executable cache)")


if __name__ == "__main__":
    main()
