"""Quickstart: build a labeled graph, plan a query with Algorithm 2, match.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import QueryGraph, SubgraphMatcher, stwig_order_selection
from repro.graphstore import PartitionedGraph, generators


def main() -> None:
    # an R-MAT graph standing in for a real labeled network
    g = generators.rmat(n_nodes=2000, n_edges=8000, n_labels=24, seed=0)
    pg = PartitionedGraph.build(g, n_shards=1)
    matcher = SubgraphMatcher(pg)

    # the paper's running example shape: a 6-node query
    #     a - b - d - e      (labels are ints)
    #         |   |
    #         c   f
    q = QueryGraph.build(
        labels=[0, 1, 2, 3, 4, 5],
        edges=[(0, 1), (1, 2), (1, 3), (3, 4), (3, 5)],
    )

    dec = stwig_order_selection(q, pg.freq)
    print("STwig decomposition (Algorithm 2):")
    for t in dec.stwigs:
        print(f"  root q{t.root} (label {t.root_label}) -> children {t.children}")

    # the paper's pipelined serving semantics: first 1024 matches (§6.1)
    res = matcher.match(q, max_matches=1024, adaptive=False)
    print(f"\n{res.n_matches} matches (complete={res.complete})")
    print("first rows (query-node order):")
    for row in res.rows[:5]:
        print("  ", row)
    print("\nper-STwig candidate rows:", res.stats["stwig_rows"])
    print("join order:", res.stats["join_order"])
    print(f"query time: {res.stats['time_s']*1e3:.1f} ms")

    # cross-check a row
    for row in res.rows[: min(3, len(res.rows))]:
        for u, v in q.edges:
            assert row[v] in g.neighbors(row[u]) or row[u] in g.neighbors(row[v])
    print("edge-consistency spot check passed")


if __name__ == "__main__":
    main()
