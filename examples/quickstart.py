"""Quickstart: the `GraphSession` facade — open a graph, compile a query
(Algorithm 2 planning + static capacities), run it, stream it.

    PYTHONPATH=src python examples/quickstart.py

`GraphSession.open` picks the right engine (local here; sharded when a mesh
or a multi-shard partition is given), `session.compile` plans once, and the
compiled query can be run one-shot or streamed page-by-page with the
paper's pipelined first-K semantics (§6.1).
"""
from repro.api import GraphSession
from repro.core import QueryGraph, stwig_order_selection
from repro.graphstore import generators


def main() -> None:
    # an R-MAT graph standing in for a real labeled network
    g = generators.rmat(n_nodes=2000, n_edges=8000, n_labels=24, seed=0)
    session = GraphSession.open(g)  # backend="auto" → local, 1 shard
    print(session)

    # the paper's running example shape: a 6-node query
    #     a - b - d - e      (labels are ints)
    #         |   |
    #         c   f
    q = QueryGraph.build(
        labels=[0, 1, 2, 3, 4, 5],
        edges=[(0, 1), (1, 2), (1, 3), (3, 4), (3, 5)],
    )

    dec = stwig_order_selection(q, session.pg.freq)
    print("STwig decomposition (Algorithm 2):")
    for t in dec.stwigs:
        print(f"  root q{t.root} (label {t.root_label}) -> children {t.children}")

    # compile once; run with the paper's pipelined serving semantics:
    # first 1024 matches (§6.1)
    compiled = session.compile(q, max_matches=1024)
    res = compiled.run(adaptive=False)
    print(f"\n{res.n_matches} matches (complete={res.complete})")
    print("first rows (query-node order):")
    for row in res.rows[:5]:
        print("  ", row)
    print("\nper-STwig candidate rows:", res.stats.stwig_rows)
    print("join order:", res.stats.join_order)
    print(f"query time: {res.stats.time_s*1e3:.1f} ms")

    # streaming delivery: pages arrive as join blocks finish, and stopping
    # early skips the remaining blocks' work entirely
    total = 0
    for page in compiled.stream(page_size=256, max_matches=512):
        total += page.rows.shape[0]
        print(f"  page {page.index}: {page.rows.shape[0]} rows (running total {total})")

    # cross-check a row
    for row in res.rows[: min(3, len(res.rows))]:
        for u, v in q.edges:
            assert row[v] in g.neighbors(row[u]) or row[u] in g.neighbors(row[v])
    print("edge-consistency spot check passed")


if __name__ == "__main__":
    main()
