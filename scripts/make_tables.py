"""Aggregate artifacts/dryrun/*.json into the EXPERIMENTS.md roofline table."""
import json
import pathlib
import sys

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def fmt_row(d):
    if d["status"] == "skipped":
        return (f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — | — | — | "
                f"skipped | — | {d['reason'][:60]} |")
    if d["status"] != "ok":
        return (f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — | — | — | "
                f"**ERROR** | — | {d.get('error','')[:60]} |")
    r = d["roofline"]
    note = ""
    mem = d.get("memory", {})
    if mem.get("argument_bytes"):
        note = f"args {mem['argument_bytes']/2**30:.1f} GiB/chip"
    return (
        f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
        f"{r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} | "
        f"{r['t_collective_s']*1e3:.2f} | **{r['bottleneck']}** | "
        f"{r['roofline_fraction']:.3f} | {r['useful_flops_ratio']:.2f} | {note} |"
    )


def main(variant=None):
    rows = []
    for f in sorted(ART.glob("*.json")):
        d = json.loads(f.read_text())
        v = d.get("variant", "baseline")
        if variant is None and v != "baseline":
            continue
        if variant is not None and v != variant:
            continue
        rows.append((d["arch"], d["shape"], d["mesh"], fmt_row(d)))
    rows.sort()
    hdr = ("| arch | shape | mesh | t_comp ms | t_mem ms | t_coll ms | "
           "bottleneck | roofline frac | useful | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    print(hdr)
    for _, _, _, r in rows:
        print(r)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
