#!/usr/bin/env bash
# Run the repo's static analyzer (jaxpr contracts + retrace detector +
# architecture lint + collective safety + cost budgets). Exit 1 on any
# finding. Flags pass through, e.g.:
#   ./scripts/staticcheck.sh --json            report incl. cost_report
#   ./scripts/staticcheck.sh --no-engines      skip the live engine probe
#                                              (and the trace-driven passes)
#   ./scripts/staticcheck.sh --no-collectives  skip collective safety
#   ./scripts/staticcheck.sh --no-costmodel    skip budgets/cost model
#   ./scripts/staticcheck.sh --x64             jnp contracts under x64
set -euo pipefail
cd "$(dirname "$0")/.."
JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}" PYTHONPATH=src \
  exec python -m repro.analysis.staticcheck "$@"
