#!/usr/bin/env bash
# Run the repo's static analyzer (jaxpr contracts + retrace detector +
# architecture lint). Exit 1 on any finding. Flags pass through, e.g.:
#   ./scripts/staticcheck.sh --json          machine-readable report
#   ./scripts/staticcheck.sh --no-engines    skip the live engine probe
#   ./scripts/staticcheck.sh --x64           jnp contracts under x64
set -euo pipefail
cd "$(dirname "$0")/.."
JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}" PYTHONPATH=src \
  exec python -m repro.analysis.staticcheck "$@"
