"""Version compatibility shims for the installed jax.

``shard_map`` moved from ``jax.experimental.shard_map`` to the ``jax``
namespace (and its replication-check kwarg was renamed ``check_rep`` →
``check_vma``) across jax releases. Everything in this repo imports it from
here so the same source runs on both sides of the move.
"""
from __future__ import annotations

try:  # jax >= 0.6: public API, kwarg is check_vma
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]

    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x: experimental API, kwarg is check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` with a stable signature across jax versions."""
    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        **{_CHECK_KW: check_vma},
    )


def axis_size(axis_name) -> int:
    """Static mesh-axis size inside a mapped computation. ``lax.axis_size``
    is recent; older jax constant-folds ``psum(1, axis)`` to the same int."""
    from jax import lax

    try:
        return lax.axis_size(axis_name)
    except AttributeError:
        return lax.psum(1, axis_name)
