from repro.train.step import loss_for, make_serve_fns, make_train_step

__all__ = ["loss_for", "make_serve_fns", "make_train_step"]
