"""Family-generic train/serve step builders.

``make_train_step`` returns a pure function (params, opt_state, batch, step)
→ (params, opt_state, metrics) suitable for jit/pjit. Gradient accumulation
over microbatches runs as a ``lax.scan`` so XLA overlaps each microbatch's
reduce-scatter with the next microbatch's compute (the comm/compute-overlap
trick recorded in §Perf).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import GNNConfig, LMConfig, RecSysConfig
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tf


def loss_for(cfg, batch) -> Callable:
    if isinstance(cfg, LMConfig):
        return lambda p: tf.loss_fn(cfg, p, batch["tokens"])
    if isinstance(cfg, GNNConfig):
        return lambda p: gnn_lib.loss_fn(cfg, p, batch["graph"])
    if isinstance(cfg, RecSysConfig):
        return lambda p: recsys_lib.loss_fn(
            cfg, p, batch["ids"], batch["bag_mask"], batch["labels"]
        )
    raise TypeError(type(cfg))


def _split_batch(batch, n):
    def sp(x):
        if hasattr(x, "shape") and x.ndim >= 1 and x.shape[0] % n == 0:
            return x.reshape((n, x.shape[0] // n) + x.shape[1:])
        return jnp.broadcast_to(x, (n,) + getattr(x, "shape", ()))

    return jax.tree.map(sp, batch)


def make_train_step(
    cfg,
    opt_cfg: optim.AdamWConfig,
    *,
    total_steps: int = 10_000,
    warmup: int = 200,
    microbatches: int = 1,
):
    def train_step(params, opt_state, batch, step):
        if microbatches > 1:
            mb = _split_batch(batch, microbatches)

            def acc_body(carry, mbatch):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_for(cfg, mbatch))(params)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            loss, grads = jax.value_and_grad(loss_for(cfg, batch))(params)
        lr_scale = optim.cosine_warmup(step, warmup=warmup, total=total_steps)
        params, opt_state, metrics = optim.update(
            opt_cfg, grads, opt_state, params, lr_scale
        )
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_serve_fns(cfg) -> dict[str, Any]:
    """Family-specific serving entry points (used by dryrun + serve.py)."""
    if isinstance(cfg, LMConfig):
        return {
            "prefill": lambda params, tokens: tf.prefill(cfg, params, tokens),
            "decode": lambda params, cache, token, pos: tf.decode_step(
                cfg, params, cache, token, pos
            ),
        }
    if isinstance(cfg, GNNConfig):
        return {"infer": lambda params, graph: gnn_lib.forward(cfg, params, graph)}
    if isinstance(cfg, RecSysConfig):
        return {
            "score": lambda params, ids, mask: recsys_lib.forward(
                cfg, params, ids, mask
            ),
            "retrieve": lambda params, ids, mask, cand: recsys_lib.retrieval_score(
                cfg, params, ids, mask, cand
            ),
        }
    raise TypeError(type(cfg))
