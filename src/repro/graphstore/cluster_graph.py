"""Cluster graph + load-set machinery (paper §5.3, Theorems 3-5).

Preprocessing: for every pair of shards ``(i, j)`` record the set of label
pairs ``(A, B)`` such that some data edge ``u→v`` with ``T(u)=A, T(v)=B``
crosses from shard ``i`` to shard ``j``. At query time the *cluster graph*
``C`` keeps only shard pairs whose label-pair set intersects the query's edge
label pairs; BFS distances ``D_C`` then bound which remote shards can possibly
contribute joinable STwig results (Theorem 4):

    F_{k,t} = { j : D_C(k, j) <= d(r_head, r_t) }

and the head STwig is chosen to minimize total communication (Theorem 5).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClusterGraphIndex:
    """Host-side preprocessing result.

    ``pair_index`` maps a label pair key ``A * n_labels + B`` to a bool
    (S, S) shard adjacency. Stored sparsely as a dict of packed shard-pair
    sets; for the label alphabets in the paper (≤ ~420) and S ≤ 512 this is
    small. Built once per graph (linear scan over edges).
    """

    n_shards: int
    n_labels: int
    pair_index: dict[int, np.ndarray]  # key -> (n_pairs, 2) int32 shard pairs

    @staticmethod
    def build(pg) -> "ClusterGraphIndex":
        si, sj, la, lb = pg.edge_shard_pairs()
        n_labels = pg.n_labels
        # unique (label_pair, shard_pair) rows — one vectorized pass
        key = (
            (la.astype(np.int64) * n_labels + lb) * pg.n_shards + si
        ) * pg.n_shards + sj
        key = np.unique(key)
        sj_u = key % pg.n_shards
        rest = key // pg.n_shards
        si_u = rest % pg.n_shards
        lp = (rest // pg.n_shards).astype(np.int64)
        pair_index: dict[int, np.ndarray] = {}
        order = np.argsort(lp, kind="stable")
        lp, si_u, sj_u = lp[order], si_u[order], sj_u[order]
        bounds = np.searchsorted(lp, np.unique(lp), side="left")
        uniq = np.unique(lp)
        bounds = np.append(bounds, len(lp))
        for t, k in enumerate(uniq):
            s, e = bounds[t], bounds[t + 1]
            pair_index[int(k)] = np.stack(
                [si_u[s:e], sj_u[s:e]], axis=1
            ).astype(np.int32)
        return ClusterGraphIndex(pg.n_shards, n_labels, pair_index)

    # ------------------------------------------------------------ query time
    def cluster_adjacency(
        self, query_label_pairs: list[tuple[int, int]]
    ) -> np.ndarray:
        """Bool (S, S) adjacency of the query-specific cluster graph C.
        ``C[i, i]`` is always True (distance 0 to self)."""
        S = self.n_shards
        C = np.zeros((S, S), dtype=bool)
        np.fill_diagonal(C, True)
        for a, b in query_label_pairs:
            for la, lb in ((a, b), (b, a)):  # data edges are symmetrized
                pairs = self.pair_index.get(int(la) * self.n_labels + int(lb))
                if pairs is not None:
                    C[pairs[:, 0], pairs[:, 1]] = True
        return C

    @staticmethod
    def bfs_distances(C: np.ndarray) -> np.ndarray:
        """All-pairs BFS distances on the cluster graph. Unreachable = a
        large sentinel (S, never ≤ any query distance)."""
        S = C.shape[0]
        INF = np.int32(S + 1)
        D = np.full((S, S), INF, dtype=np.int32)
        reach = np.eye(S, dtype=bool)
        D[reach] = 0
        frontier = reach
        for dist in range(1, S + 1):
            nxt = (frontier @ C) & ~reach
            if not nxt.any():
                break
            D[nxt] = dist
            reach |= nxt
            frontier = nxt
        return D

    def load_sets(
        self,
        query_label_pairs: list[tuple[int, int]],
        head_to_root_dist: np.ndarray,
    ) -> np.ndarray:
        """Bool (n_stwigs, S, S) mask: entry (t, k, j) says shard k must load
        results of STwig t from shard j (Theorem 4). Row for the head STwig
        is the identity (F = ∅ plus self)."""
        C = self.cluster_adjacency(query_label_pairs)
        D = self.bfs_distances(C)
        out = np.zeros(
            (len(head_to_root_dist), self.n_shards, self.n_shards), dtype=bool
        )
        for t, d in enumerate(head_to_root_dist):
            out[t] = D <= np.int32(d)
        return out
