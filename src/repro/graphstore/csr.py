"""Host-side labeled graph with CSR adjacency.

This is the construction-time representation. ``PartitionedGraph``
(partition.py) turns it into the sharded, padded, device-ready layout used by
the matching engine and the GNN models.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Graph:
    """A labeled graph in CSR form (host / numpy).

    Attributes:
      n_nodes:   number of vertices.
      n_labels:  size of the label alphabet; labels are ints in [0, n_labels).
      labels:    (n_nodes,) int32 vertex labels.
      indptr:    (n_nodes+1,) int64 CSR row pointers.
      indices:   (n_edges,) int32 CSR column indices (out-neighbors).
      directed:  whether ``indices`` is a directed out-adjacency. The STwig
                 matcher follows edges as stored; for undirected semantics
                 build with ``symmetrize=True`` (the default used everywhere
                 in the paper's experiments).
    """

    n_nodes: int
    n_labels: int
    labels: np.ndarray
    indptr: np.ndarray
    indices: np.ndarray
    directed: bool = False

    # ------------------------------------------------------------------ build
    @staticmethod
    def from_edges(
        n_nodes: int,
        src: np.ndarray,
        dst: np.ndarray,
        labels: np.ndarray,
        n_labels: int,
        *,
        symmetrize: bool = True,
        dedup: bool = True,
    ) -> "Graph":
        """Build a CSR graph from an edge list.

        Self-loops are removed (the paper's query graphs are simple graphs).
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        keep = src != dst
        src, dst = src[keep], dst[keep]
        if symmetrize:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        if dedup and len(src):
            key = src * n_nodes + dst
            key = np.unique(key)
            src, dst = key // n_nodes, key % n_nodes
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return Graph(
            n_nodes=n_nodes,
            n_labels=n_labels,
            labels=np.asarray(labels, dtype=np.int32),
            indptr=indptr,
            indices=dst.astype(np.int32),
            directed=not symmetrize,
        )

    # ------------------------------------------------------------- accessors
    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    def max_degree(self) -> int:
        return int(self.degrees().max()) if self.n_nodes else 0

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def label_frequencies(self) -> np.ndarray:
        """freq(l) = number of data nodes with label l (paper §5.2 f-values)."""
        return np.bincount(self.labels, minlength=self.n_labels).astype(np.int64)

    # ----------------------------------------------------------------- utils
    def relabel(self, perm: np.ndarray) -> "Graph":
        """Apply a node permutation: new_id = perm[old_id]."""
        inv = np.empty_like(perm)
        inv[perm] = np.arange(self.n_nodes, dtype=perm.dtype)
        new_src = np.repeat(perm, np.diff(self.indptr))
        new_dst = perm[self.indices]
        return Graph.from_edges(
            self.n_nodes,
            new_src,
            new_dst,
            self.labels[inv],
            self.n_labels,
            symmetrize=False,
            dedup=False,
        )

    def memory_bytes(self) -> int:
        return (
            self.labels.nbytes + self.indptr.nbytes + self.indices.nbytes
        )
