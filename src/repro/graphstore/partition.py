"""Hash partitioning of a graph into the shard-block device layout.

The paper (§4.3) hash-partitions nodes across machines and keeps a *local*
string index per machine. Our TPU translation re-labels nodes so that shard
``s`` owns the contiguous global-ID block ``[s*cap, (s+1)*cap)``:

  * ``shard_of(id) = id // cap`` is a shift, not a hash lookup;
  * every per-shard array is the same (padded) size, so the stacked arrays
    shard evenly along a mesh axis with ``shard_map``;
  * neighbor lists store *global* new IDs, so cross-shard exploration is a
    gather + bit-test instead of an RPC (see DESIGN.md §2).

Padded entries use sentinels: node slots → label ``n_labels`` (invalid),
edge slots → global id ``n_total`` (one-past-the-end ghost node whose label is
invalid and whose binding bits are never set).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphstore.csr import Graph


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Deterministic 64-bit mixer (the partitioning hash function)."""
    x = (x.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def shard_of(old_ids: np.ndarray, n_shards: int, mode: str = "hash") -> np.ndarray:
    if mode == "hash":
        return (_splitmix64(np.asarray(old_ids)) % np.uint64(n_shards)).astype(
            np.int32
        )
    raise ValueError(f"unknown partition mode {mode!r}")


@dataclasses.dataclass
class PartitionedGraph:
    """Device-ready sharded graph. All stacked arrays have a leading shard
    axis and identical per-shard padding so they map onto a mesh axis."""

    n_shards: int
    n_nodes: int          # real node count (before padding)
    n_labels: int
    cap: int              # padded nodes per shard
    edge_cap: int         # padded edges per shard
    # --- stacked per-shard arrays, leading axis = shard -------------------
    labels: np.ndarray        # (S, cap) int32, pad = n_labels
    n_local: np.ndarray       # (S,) int32 real node count per shard
    n_local_edges: np.ndarray  # (S,) int32 real edge count per shard
    indptr: np.ndarray        # (S, cap+1) int32 local CSR
    indices: np.ndarray       # (S, edge_cap) int32 GLOBAL new ids, pad = n_total
    edge_src: np.ndarray      # (S, edge_cap) int32 local src row per edge, pad = cap
    label_indptr: np.ndarray  # (S, n_labels+1) int32
    nodes_by_label: np.ndarray  # (S, cap) int32 local ids grouped by label
    # --- replicated --------------------------------------------------------
    all_labels: np.ndarray    # (n_total+1,) int32 global labels, pad = n_labels
    freq: np.ndarray          # (n_labels,) int64 global label frequencies
    # --- host-only mappings -------------------------------------------------
    old_to_new: np.ndarray    # (n_nodes,) int64
    new_to_old: np.ndarray    # (n_total,) int64, pad slots = -1

    @property
    def n_total(self) -> int:
        return self.n_shards * self.cap

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(
        g: Graph,
        n_shards: int,
        *,
        mode: str = "hash",
        pad_to_multiple: int = 8,
    ) -> "PartitionedGraph":
        n = g.n_nodes
        if mode == "range":
            shard = (np.arange(n, dtype=np.int64) * n_shards // max(n, 1)).astype(
                np.int32
            )
        else:
            shard = shard_of(np.arange(n, dtype=np.int64), n_shards, mode)
        counts = np.bincount(shard, minlength=n_shards)
        cap = int(counts.max()) if n else 1
        cap = max(1, -(-cap // pad_to_multiple) * pad_to_multiple)
        n_total = n_shards * cap

        # stable order: sort nodes by shard → local slot = rank within shard
        order = np.argsort(shard, kind="stable")           # old ids grouped by shard
        local_rank = np.zeros(n, dtype=np.int64)
        local_rank[order] = np.arange(n) - np.repeat(
            np.concatenate([[0], np.cumsum(counts)[:-1]]), counts
        )
        old_to_new = shard.astype(np.int64) * cap + local_rank
        new_to_old = np.full(n_total, -1, dtype=np.int64)
        new_to_old[old_to_new] = np.arange(n, dtype=np.int64)

        # relabeled global label array (+ ghost entry)
        all_labels = np.full(n_total + 1, g.n_labels, dtype=np.int32)
        all_labels[old_to_new] = g.labels

        # per-shard CSR over new ids (neighbors stay GLOBAL new ids)
        new_src = old_to_new[np.repeat(np.arange(n), np.diff(g.indptr))]
        new_dst = old_to_new[g.indices]
        e_order = np.argsort(new_src, kind="stable")
        new_src, new_dst = new_src[e_order], new_dst[e_order]
        e_shard = (new_src // cap).astype(np.int32)
        e_counts = np.bincount(e_shard, minlength=n_shards)
        edge_cap = int(e_counts.max()) if len(new_src) else 1
        edge_cap = max(1, -(-edge_cap // pad_to_multiple) * pad_to_multiple)

        labels_sh = np.full((n_shards, cap), g.n_labels, dtype=np.int32)
        indptr_sh = np.zeros((n_shards, cap + 1), dtype=np.int32)
        indices_sh = np.full((n_shards, edge_cap), n_total, dtype=np.int32)
        edge_src_sh = np.full((n_shards, edge_cap), cap, dtype=np.int32)
        label_indptr = np.zeros((n_shards, g.n_labels + 1), dtype=np.int32)
        nodes_by_label = np.full((n_shards, cap), cap, dtype=np.int32)

        e_starts = np.concatenate([[0], np.cumsum(e_counts)])
        for s in range(n_shards):
            nl = int(counts[s])
            lab = all_labels[s * cap : s * cap + cap]
            labels_sh[s] = lab
            # local CSR
            es, ee = e_starts[s], e_starts[s + 1]
            loc_src = (new_src[es:ee] - s * cap).astype(np.int32)
            ptr = np.zeros(cap + 1, dtype=np.int64)
            np.add.at(ptr, loc_src + 1, 1)
            indptr_sh[s] = np.cumsum(ptr).astype(np.int32)
            ne = ee - es
            indices_sh[s, :ne] = new_dst[es:ee].astype(np.int32)
            edge_src_sh[s, :ne] = loc_src
            # local label index: local ids grouped by label
            valid = np.arange(cap) < nl
            lab_valid = np.where(valid, lab, g.n_labels)
            lorder = np.argsort(lab_valid[:nl], kind="stable")
            nodes_by_label[s, :nl] = lorder.astype(np.int32)
            lptr = np.zeros(g.n_labels + 1, dtype=np.int64)
            np.add.at(lptr, lab_valid[:nl] + 1, 1)
            label_indptr[s] = np.cumsum(lptr)[: g.n_labels + 1].astype(np.int32)

        return PartitionedGraph(
            n_shards=n_shards,
            n_nodes=n,
            n_labels=g.n_labels,
            cap=cap,
            edge_cap=edge_cap,
            labels=labels_sh,
            n_local=counts.astype(np.int32),
            n_local_edges=e_counts.astype(np.int32),
            indptr=indptr_sh,
            indices=indices_sh,
            edge_src=edge_src_sh,
            label_indptr=label_indptr,
            nodes_by_label=nodes_by_label,
            all_labels=all_labels,
            freq=g.label_frequencies(),
            old_to_new=old_to_new,
            new_to_old=new_to_old,
        )

    # --------------------------------------------------------------- helpers
    def shard_of_global(self, new_ids: np.ndarray) -> np.ndarray:
        return np.minimum(new_ids // self.cap, self.n_shards - 1)

    def edge_shard_pairs(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(src_shard, dst_shard, src_label, dst_label) per real edge —
        the input to cluster-graph preprocessing (§5.3)."""
        srcs, dsts = [], []
        for s in range(self.n_shards):
            ne = int(self.n_local_edges[s])
            loc = self.edge_src[s, :ne].astype(np.int64) + s * self.cap
            srcs.append(loc)
            dsts.append(self.indices[s, :ne].astype(np.int64))
        src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
        dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
        return (
            (src // self.cap).astype(np.int32),
            (dst // self.cap).astype(np.int32),
            self.all_labels[src],
            self.all_labels[dst],
        )

    def memory_bytes(self) -> int:
        tot = 0
        for f in (
            self.labels, self.indptr, self.indices, self.edge_src,
            self.label_indptr, self.nodes_by_label, self.all_labels,
        ):
            tot += f.nbytes
        return tot
