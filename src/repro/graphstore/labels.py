"""Label index + packed-bitset re-exports.

The paper's only index is the *string index* (label → node IDs): linear space,
linear build, O(1) update (Table 1 row "STwig"). Here the label alphabet is
already integer-coded, so the index is a per-shard counting sort — built once
in ``PartitionedGraph.build``; this module provides the query-side helpers.

The packed-uint32 bitsets that replace Trinity's remote ``hasLabel`` /
binding-set RPCs (DESIGN.md §2) live in `repro.kernels.bitset.ref` — the
single canonical implementation, registered as the ``jnp`` backend by
`repro.core.backend` — and are only re-exported here for compatibility. No
bit twiddling happens in this package.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.bitset.ref import (  # noqa: F401  (compat re-exports)
    WORD_BITS,
    bitset_test_np,
    build_reference as jnp_bitset_build,
    lookup_reference as jnp_bitset_test,
    n_words,
    or_reference as jnp_bitset_or,
    pack_bitset,
    popcount_reference as jnp_bitset_popcount,
    unpack_bitset,
)


class LabelIndex:
    """Query-side view over the per-shard label index built by
    ``PartitionedGraph.build`` — the paper's ``Index.getID(label)``."""

    def __init__(self, pg) -> None:
        self._pg = pg

    def get_ids(self, shard: int, label: int) -> np.ndarray:
        """Local ids of nodes with ``label`` on ``shard`` (host-side)."""
        s, e = self._pg.label_indptr[shard, label], self._pg.label_indptr[
            shard, label + 1
        ]
        return self._pg.nodes_by_label[shard, s:e]

    def count(self, shard: int, label: int) -> int:
        return int(
            self._pg.label_indptr[shard, label + 1]
            - self._pg.label_indptr[shard, label]
        )

    def has_label(self, global_ids: np.ndarray, label: int) -> np.ndarray:
        """The paper's ``Index.hasLabel`` — vectorized."""
        return self._pg.all_labels[np.asarray(global_ids)] == label
