"""Label index + packed-bitset utilities.

The paper's only index is the *string index* (label → node IDs): linear space,
linear build, O(1) update (Table 1 row "STwig"). Here the label alphabet is
already integer-coded, so the index is a per-shard counting sort — built once
in ``PartitionedGraph.build``; this module provides the query-side helpers and
the packed-uint32 bitsets that replace Trinity's remote ``hasLabel`` /
binding-set RPCs (DESIGN.md §2).

Bitset convention: bit ``i`` of word ``i // 32`` is ``(w >> (i % 32)) & 1``.
Bitsets cover global ids ``[0, n_total]`` inclusive of the ghost id
``n_total`` (always 0).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

WORD_BITS = 32


def n_words(n_bits: int) -> int:
    return (n_bits + WORD_BITS - 1) // WORD_BITS


# --------------------------------------------------------------------- numpy
def pack_bitset(mask: np.ndarray) -> np.ndarray:
    """Pack a bool array (n,) into uint32 words (ceil(n/32),)."""
    n = mask.shape[0]
    pad = (-n) % WORD_BITS
    m = np.concatenate([mask.astype(np.uint8), np.zeros(pad, np.uint8)])
    bits = m.reshape(-1, WORD_BITS).astype(np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    return (bits << shifts).sum(axis=1, dtype=np.uint32)


def unpack_bitset(words: np.ndarray, n_bits: int) -> np.ndarray:
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[:, None] >> shifts) & np.uint32(1)
    return bits.reshape(-1)[:n_bits].astype(bool)


def bitset_test_np(words: np.ndarray, ids: np.ndarray) -> np.ndarray:
    w = words[ids // WORD_BITS]
    return ((w >> (ids % WORD_BITS).astype(np.uint32)) & np.uint32(1)).astype(bool)


# ----------------------------------------------------------------------- jnp
def jnp_bitset_test(words: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Vectorized membership test. ``ids`` int32 >= 0; out-of-range ids clamp
    onto the final (always-zero) ghost word region — callers pad with the
    ghost id, never with a real id."""
    word_idx = ids // WORD_BITS
    bit = (ids % WORD_BITS).astype(jnp.uint32)
    w = jnp.take(words, word_idx, mode="clip")
    return ((w >> bit) & jnp.uint32(1)).astype(jnp.bool_)


def jnp_bitset_build(ids: jnp.ndarray, valid: jnp.ndarray, nwords: int) -> jnp.ndarray:
    """Build a packed bitset from (possibly duplicated) ids with a validity
    mask. XLA has no scatter-OR combiner, so scatter booleans then pack 32
    lanes per word (duplicate-safe); the Pallas `bitset` kernel does the
    packed scatter-OR natively on TPU."""
    n_bits = nwords * WORD_BITS
    idx = jnp.where(valid, ids, n_bits)
    bits = jnp.zeros((n_bits,), jnp.bool_).at[idx].set(True, mode="drop")
    lanes = bits.reshape(nwords, WORD_BITS).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(lanes << shifts, axis=1, dtype=jnp.uint32)


def jnp_bitset_or(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.bitwise_or(a, b)


def jnp_bitset_popcount(words: jnp.ndarray) -> jnp.ndarray:
    """Total number of set bits (binding-set cardinality, used by the join
    order cost model)."""
    return jnp.sum(_popcount32(words))


def _popcount32(w: jnp.ndarray) -> jnp.ndarray:
    w = w - ((w >> 1) & jnp.uint32(0x55555555))
    w = (w & jnp.uint32(0x33333333)) + ((w >> 2) & jnp.uint32(0x33333333))
    w = (w + (w >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (w * jnp.uint32(0x01010101)) >> 24


class LabelIndex:
    """Query-side view over the per-shard label index built by
    ``PartitionedGraph.build`` — the paper's ``Index.getID(label)``."""

    def __init__(self, pg) -> None:
        self._pg = pg

    def get_ids(self, shard: int, label: int) -> np.ndarray:
        """Local ids of nodes with ``label`` on ``shard`` (host-side)."""
        s, e = self._pg.label_indptr[shard, label], self._pg.label_indptr[
            shard, label + 1
        ]
        return self._pg.nodes_by_label[shard, s:e]

    def count(self, shard: int, label: int) -> int:
        return int(
            self._pg.label_indptr[shard, label + 1]
            - self._pg.label_indptr[shard, label]
        )

    def has_label(self, global_ids: np.ndarray, label: int) -> np.ndarray:
        """The paper's ``Index.hasLabel`` — vectorized."""
        return self._pg.all_labels[np.asarray(global_ids)] == label
