"""Synthetic graph generators.

The paper evaluates on R-MAT graphs (§6.3, citing Chakrabarti et al. [8]) plus
two real datasets (US Patents, WordNet). The container is offline, so the real
datasets are replaced by R-MAT graphs with matched node/edge/label counts;
each benchmark notes the substitution.
"""
from __future__ import annotations

import numpy as np

from repro.graphstore.csr import Graph


def assign_labels(
    n_nodes: int, n_labels: int, rng: np.random.Generator, *, zipf_s: float = 0.0
) -> np.ndarray:
    """Assign labels; ``zipf_s > 0`` gives a power-law label distribution
    (real graphs' labels are skewed; the paper calls this *label density*)."""
    if zipf_s <= 0.0:
        return rng.integers(0, n_labels, size=n_nodes, dtype=np.int32)
    w = 1.0 / np.arange(1, n_labels + 1, dtype=np.float64) ** zipf_s
    w /= w.sum()
    return rng.choice(n_labels, size=n_nodes, p=w).astype(np.int32)


def rmat(
    n_nodes: int,
    n_edges: int,
    n_labels: int,
    *,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    zipf_s: float = 0.0,
    symmetrize: bool = True,
) -> Graph:
    """R-MAT recursive matrix generator [Chakrabarti et al., SDM'04].

    Vectorized: all edges draw their quadrant bits at once, one level of the
    recursion per bit of ``log2(n)``.
    """
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(n_nodes, 2))))
    d = 1.0 - a - b - c
    probs = np.array([a, b, c, d])
    thresholds = np.cumsum(probs)
    src = np.zeros(n_edges, dtype=np.int64)
    dst = np.zeros(n_edges, dtype=np.int64)
    for _ in range(scale):
        r = rng.random(n_edges)
        quad = np.searchsorted(thresholds, r)
        src = (src << 1) | (quad >> 1)
        dst = (dst << 1) | (quad & 1)
    src %= n_nodes
    dst %= n_nodes
    labels = assign_labels(n_nodes, n_labels, rng, zipf_s=zipf_s)
    return Graph.from_edges(
        n_nodes, src, dst, labels, n_labels, symmetrize=symmetrize
    )


def erdos_renyi(
    n_nodes: int, n_edges: int, n_labels: int, *, seed: int = 0
) -> Graph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=n_edges)
    dst = rng.integers(0, n_nodes, size=n_edges)
    labels = assign_labels(n_nodes, n_labels, rng)
    return Graph.from_edges(n_nodes, src, dst, labels, n_labels)


def ring_of_cliques(
    n_cliques: int, clique_size: int, n_labels: int, *, seed: int = 0
) -> Graph:
    """Cliques joined in a ring — a high-locality graph. When partitioned by
    node ranges, its cluster graph (§5.3) is a ring, so load sets are small:
    the fixture used to exercise Theorems 3-5 in a non-degenerate way."""
    rng = np.random.default_rng(seed)
    n_nodes = n_cliques * clique_size
    srcs, dsts = [], []
    base = np.arange(clique_size)
    iu, ju = np.triu_indices(clique_size, k=1)
    for c in range(n_cliques):
        off = c * clique_size
        srcs.append(iu + off)
        dsts.append(ju + off)
        # one bridge edge to the next clique
        srcs.append(np.array([off + clique_size - 1]))
        dsts.append(np.array([(off + clique_size) % n_nodes]))
    labels = assign_labels(n_nodes, n_labels, rng)
    return Graph.from_edges(
        n_nodes, np.concatenate(srcs), np.concatenate(dsts), labels, n_labels
    )


def grid_2d(rows: int, cols: int, n_labels: int, *, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    n = rows * cols
    idx = np.arange(n).reshape(rows, cols)
    src = np.concatenate([idx[:, :-1].ravel(), idx[:-1, :].ravel()])
    dst = np.concatenate([idx[:, 1:].ravel(), idx[1:, :].ravel()])
    labels = assign_labels(n, n_labels, rng)
    return Graph.from_edges(n, src, dst, labels, n_labels)


def paper_fig6_query_edges() -> tuple[list[tuple[str, str]], dict[str, str]]:
    """The §5.2 walkthrough query: used to unit-test Algorithm 2.

    Nodes a..f; Algorithm 2 with freq(l)=10 for all labels must produce
    T1={d,(b,c,e,f)}, T2={c,(a,f)}, T3={b,(a,f)}.
    """
    edges = [
        ("d", "b"), ("d", "c"), ("d", "e"), ("d", "f"),
        ("c", "a"), ("c", "f"), ("b", "a"), ("b", "f"),
    ]
    labels = {v: v for v in "abcdef"}
    return edges, labels


def molecule_batch(
    n_graphs: int,
    nodes_per_graph: int,
    avg_degree: float,
    n_labels: int,
    *,
    seed: int = 0,
) -> Graph:
    """A disjoint union of small random molecules (batched-small-graphs
    regime). Returned as one block-diagonal graph; `graph_id = node //
    nodes_per_graph`."""
    rng = np.random.default_rng(seed)
    n = n_graphs * nodes_per_graph
    e_per = max(1, int(nodes_per_graph * avg_degree / 2))
    src = rng.integers(0, nodes_per_graph, size=(n_graphs, e_per))
    dst = rng.integers(0, nodes_per_graph, size=(n_graphs, e_per))
    offs = (np.arange(n_graphs) * nodes_per_graph)[:, None]
    labels = assign_labels(n, n_labels, rng)
    return Graph.from_edges(
        n, (src + offs).ravel(), (dst + offs).ravel(), labels, n_labels
    )
