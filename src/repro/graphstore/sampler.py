"""Fanout neighbor sampler (GraphSAGE-style) for minibatch GNN training.

The ``minibatch_lg`` shape regime (batch_nodes=1024, fanout 15-10) needs a
real sampler: given seed nodes, sample up to ``fanout[l]`` neighbors per node
per hop from the CSR adjacency, emit a padded subgraph (node list + edge
index) with static shapes so the jitted train step never recompiles.

Host-side numpy (samplers are data-pipeline work, they run on CPU feeders in
a real deployment); the output tensors are what ``input_specs`` mirrors for
the dry-run.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphstore.csr import Graph


@dataclasses.dataclass
class SampledSubgraph:
    """Padded k-hop sampled subgraph.

    nodes:     (n_node_cap,) int32 global node ids, pad = -1
    n_nodes:   int, real count
    edge_src:  (n_edge_cap,) int32 *local* indices into ``nodes``
    edge_dst:  (n_edge_cap,) int32 local indices (messages flow src -> dst)
    edge_mask: (n_edge_cap,) bool
    seed_mask: (n_node_cap,) bool — which rows are the labeled seed nodes
    """

    nodes: np.ndarray
    n_nodes: int
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    seed_mask: np.ndarray

    @property
    def node_cap(self) -> int:
        return int(self.nodes.shape[0])

    @property
    def edge_cap(self) -> int:
        return int(self.edge_src.shape[0])


class NeighborSampler:
    def __init__(self, g: Graph, fanouts: tuple[int, ...], *, seed: int = 0):
        self.g = g
        self.fanouts = tuple(int(f) for f in fanouts)
        self.rng = np.random.default_rng(seed)

    def capacities(self, batch_nodes: int) -> tuple[int, int]:
        """Static (node_cap, edge_cap) implied by batch size and fanouts."""
        node_cap = batch_nodes
        edge_cap = 0
        frontier = batch_nodes
        for f in self.fanouts:
            edge_cap += frontier * f
            frontier *= f
            node_cap += frontier
        return node_cap, edge_cap

    def sample(self, seeds: np.ndarray) -> SampledSubgraph:
        """Sample the k-hop neighborhood of ``seeds`` with per-hop fanouts."""
        g, rng = self.g, self.rng
        seeds = np.asarray(seeds, dtype=np.int64)
        node_cap, edge_cap = self.capacities(len(seeds))

        id_of: dict[int, int] = {}
        nodes: list[int] = []

        def intern(vs: np.ndarray) -> np.ndarray:
            out = np.empty(len(vs), dtype=np.int32)
            for i, v in enumerate(vs):
                j = id_of.get(int(v))
                if j is None:
                    j = len(nodes)
                    id_of[int(v)] = j
                    nodes.append(int(v))
                out[i] = j
            return out

        intern(seeds)
        frontier = seeds
        e_src: list[np.ndarray] = []
        e_dst: list[np.ndarray] = []
        for f in self.fanouts:
            nbr_src, nbr_dst = [], []
            deg = np.diff(g.indptr)[frontier]
            for v, d in zip(frontier, deg):
                if d == 0:
                    continue
                take = min(int(d), f)
                if d <= f:
                    picks = g.indices[g.indptr[v] : g.indptr[v + 1]]
                else:
                    offs = rng.choice(int(d), size=take, replace=False)
                    picks = g.indices[g.indptr[v] + offs]
                nbr_src.append(np.full(take, v, dtype=np.int64))
                nbr_dst.append(picks.astype(np.int64))
            if not nbr_src:
                break
            s = np.concatenate(nbr_src)
            t = np.concatenate(nbr_dst)
            # messages flow neighbor -> center: edge (t -> s)
            e_src.append(intern(t))
            e_dst.append(intern(s))
            frontier = np.unique(t)

        src = np.concatenate(e_src) if e_src else np.zeros(0, np.int32)
        dst = np.concatenate(e_dst) if e_dst else np.zeros(0, np.int32)
        n_real_e = len(src)
        n_real_n = len(nodes)
        assert n_real_n <= node_cap and n_real_e <= edge_cap, (
            n_real_n, node_cap, n_real_e, edge_cap,
        )

        nodes_arr = np.full(node_cap, -1, dtype=np.int32)
        nodes_arr[:n_real_n] = np.asarray(nodes, dtype=np.int32)
        edge_src = np.zeros(edge_cap, dtype=np.int32)
        edge_dst = np.zeros(edge_cap, dtype=np.int32)
        edge_mask = np.zeros(edge_cap, dtype=bool)
        edge_src[:n_real_e] = src
        edge_dst[:n_real_e] = dst
        edge_mask[:n_real_e] = True
        seed_mask = np.zeros(node_cap, dtype=bool)
        seed_mask[: len(seeds)] = True
        return SampledSubgraph(
            nodes=nodes_arr,
            n_nodes=n_real_n,
            edge_src=edge_src,
            edge_dst=edge_dst,
            edge_mask=edge_mask,
            seed_mask=seed_mask,
        )
