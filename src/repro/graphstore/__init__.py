"""Graph storage substrate: the Trinity-memory-cloud analogue for a TPU mesh.

Host-side (numpy) graph construction, hash partitioning into shard-block
layout, label indices, cluster-graph preprocessing, synthetic generators and
the neighbor sampler used for GNN minibatch training.
"""
from repro.graphstore.csr import Graph
from repro.graphstore.partition import PartitionedGraph, shard_of
from repro.graphstore.labels import LabelIndex, pack_bitset, unpack_bitset, bitset_test_np
from repro.graphstore.cluster_graph import ClusterGraphIndex
from repro.graphstore import generators
from repro.graphstore.sampler import NeighborSampler

__all__ = [
    "Graph",
    "PartitionedGraph",
    "shard_of",
    "LabelIndex",
    "pack_bitset",
    "unpack_bitset",
    "bitset_test_np",
    "ClusterGraphIndex",
    "generators",
    "NeighborSampler",
]
