from repro.kernels.bitset.bitset import (
    bitset_lookup,
    bitset_pack,
    bitset_unpack,
    candidate_filter,
)
from repro.kernels.bitset import ops, ref

__all__ = [
    "bitset_lookup",
    "bitset_pack",
    "bitset_unpack",
    "candidate_filter",
    "ops",
    "ref",
]
