"""Packed-bitset kernels. `ref` (pure jnp, light) loads eagerly; the Pallas
kernel module only loads when one of its ops is first touched, so jnp-only
sessions never pay the jax.experimental.pallas import.

NOTE: the old `ops.py` jitted use_pallas/jnp dispatch was deleted — backend
selection lives in the `Kernels` registry (`repro.core.backend`) now.
"""
from repro.kernels.bitset import ref

_PALLAS_OPS = ("bitset_lookup", "bitset_pack", "bitset_unpack", "candidate_filter")

__all__ = [*_PALLAS_OPS, "ref"]


def __getattr__(name):  # PEP 562 lazy import of the Pallas kernels
    if name in _PALLAS_OPS:
        from repro.kernels.bitset import bitset

        fn = getattr(bitset, name)
        globals()[name] = fn  # cache: bypass __getattr__ next time
        return fn
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
