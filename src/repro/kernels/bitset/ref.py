"""Pure-jnp oracles for the bitset kernels (shared with graphstore.labels)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.graphstore.labels import WORD_BITS


def unpack_reference(words: jnp.ndarray) -> jnp.ndarray:
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(-1).astype(jnp.bool_)


def pack_reference(mask: jnp.ndarray) -> jnp.ndarray:
    n = mask.shape[0]
    lanes = mask.reshape(n // WORD_BITS, WORD_BITS).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(lanes << shifts, axis=1, dtype=jnp.uint32)


def lookup_reference(words: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    w = jnp.take(words, ids // WORD_BITS, mode="clip")
    return ((w >> (ids % WORD_BITS).astype(jnp.uint32)) & 1).astype(jnp.bool_)


def candidate_filter_reference(words, dst_ids, dst_labels, root_ok, child_label):
    """Oracle for the fused MatchSTwig step-2 filter (matches core.match)."""
    return root_ok & (dst_labels == child_label) & lookup_reference(words, dst_ids)
