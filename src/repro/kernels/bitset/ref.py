"""Canonical packed-bitset ops: the pure-jnp reference implementations and
the numpy host-side helpers.

This module is the single source of truth for the packed-uint32 convention —
bit ``i`` of word ``i // 32`` is ``(w >> (i % 32)) & 1``, bitsets cover
global ids ``[0, n_total]`` inclusive of the always-zero ghost id (DESIGN.md
§2). ``repro.graphstore.labels`` re-exports the helpers and the ``jnp``
`Kernels` backend (`repro.core.backend`) registers the reference ops; no
other module does its own bit twiddling.

Out-of-range semantics: ``lookup_reference`` (and the Pallas kernel it is
the oracle for) maps negative or past-the-end ids to ``False`` — an id that
names no bit is a member of no set. (An earlier version clipped, silently
aliasing bad ids onto word 0 / the last word.)
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

WORD_BITS = 32


def n_words(n_bits: int) -> int:
    return (n_bits + WORD_BITS - 1) // WORD_BITS


# --------------------------------------------------------------------- numpy
def pack_bitset(mask: np.ndarray) -> np.ndarray:
    """Pack a bool array (n,) into uint32 words (ceil(n/32),)."""
    n = mask.shape[0]
    pad = (-n) % WORD_BITS
    m = np.concatenate([mask.astype(np.uint8), np.zeros(pad, np.uint8)])
    bits = m.reshape(-1, WORD_BITS).astype(np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    return (bits << shifts).sum(axis=1, dtype=np.uint32)


def unpack_bitset(words: np.ndarray, n_bits: int) -> np.ndarray:
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[:, None] >> shifts) & np.uint32(1)
    return bits.reshape(-1)[:n_bits].astype(bool)


def bitset_test_np(words: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Host-side membership test; ids must be in range (no masking)."""
    w = words[ids // WORD_BITS]
    return ((w >> (ids % WORD_BITS).astype(np.uint32)) & np.uint32(1)).astype(bool)


# ----------------------------------------------------------------------- jnp
def unpack_reference(words: jnp.ndarray) -> jnp.ndarray:
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(-1).astype(jnp.bool_)


def pack_reference(mask: jnp.ndarray) -> jnp.ndarray:
    n = mask.shape[0]
    lanes = mask.reshape(n // WORD_BITS, WORD_BITS).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(lanes << shifts, axis=1, dtype=jnp.uint32)


def lookup_reference(words: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Vectorized membership test. Negative or out-of-range ids are ``False``
    (not clipped onto a real word)."""
    # np.int32 keeps the word-index math at 32 bits even under x64, where a
    # bare Python literal would arrive as an int64 scalar operand
    wb = np.int32(WORD_BITS)
    in_range = (ids >= 0) & (ids < np.int32(words.shape[0] * WORD_BITS))
    w = jnp.take(words, ids // wb, mode="clip")
    bit = ((w >> (ids % wb).astype(jnp.uint32)) & jnp.uint32(1)) > 0
    return bit & in_range


def build_reference(ids: jnp.ndarray, valid: jnp.ndarray, nwords: int) -> jnp.ndarray:
    """Build a packed bitset from (possibly duplicated) ids with a validity
    mask. XLA has no scatter-OR combiner over packed words (duplicate ids
    landing in one word would need an OR accumulator), so this is the
    closest single-pass shape: one byte-lane scatter (duplicate-safe — all
    updates write the same 1), then a 32-lane shift-OR fold per word. The
    lanes scatter at uint8 instead of bool so the fold widens straight to
    the word dtype; the Pallas backend runs the fold in-kernel."""
    n_bits = nwords * WORD_BITS
    idx = jnp.where(valid, ids, np.int32(n_bits))
    lanes = jnp.zeros((n_bits,), jnp.uint8).at[idx].set(
        np.uint8(1), mode="drop"
    )
    lanes32 = lanes.reshape(nwords, WORD_BITS).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(lanes32 << shifts, axis=1, dtype=jnp.uint32)


def or_reference(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.bitwise_or(a, b)


def popcount_reference(words: jnp.ndarray) -> jnp.ndarray:
    """Total number of set bits (binding-set cardinality)."""
    return jnp.sum(_popcount32(words))


def _popcount32(w: jnp.ndarray) -> jnp.ndarray:
    w = w - ((w >> 1) & jnp.uint32(0x55555555))
    w = (w & jnp.uint32(0x33333333)) + ((w >> 2) & jnp.uint32(0x33333333))
    w = (w + (w >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (w * jnp.uint32(0x01010101)) >> 24


def candidate_filter_reference(words, dst_ids, dst_labels, root_ok, child_label):
    """Oracle for the fused MatchSTwig step-2 filter (matches core.match)."""
    return root_ok & (dst_labels == child_label) & lookup_reference(words, dst_ids)
