"""Pallas TPU kernels for packed binding bitsets (DESIGN.md §2; registered
as the ``pallas`` backend's bitset ops in `repro.core.backend`).

Two layouts matter in the matcher:
  * *range* ops — root-candidate masks over the shard's own contiguous id
    block: fully vectorized unpack/pack (bit algebra over aligned tiles).
  * *gather* ops — membership tests for arbitrary (remote) ids:
    ``bitset_lookup`` gathers one word per id from the VMEM-resident bitset
    (TPU dynamic-gather; ids tiled over the grid).

The packed uint32 convention matches ``repro.graphstore.labels``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the reference lookup is pure jnp on values, so the kernels reuse it on
# their VMEM blocks — one copy of the masked bit-twiddle, everywhere
from repro.kernels.bitset.ref import WORD_BITS, lookup_reference


# ----------------------------------------------------------------- unpack
def _unpack_kernel(w_ref, o_ref, *, bw: int):
    w = w_ref[...]  # (BW,)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (bw, WORD_BITS), 1)
    bits = (w[:, None] >> shifts) & jnp.uint32(1)
    o_ref[...] = bits.astype(jnp.bool_).reshape(bw * WORD_BITS)


def bitset_unpack(words: jnp.ndarray, *, bw: int = 512, interpret: bool = False):
    """(W,) uint32 → (W*32,) bool, tiled over word blocks."""
    W = words.shape[0]
    bw = min(bw, W)
    while W % bw:
        bw //= 2
    return pl.pallas_call(
        functools.partial(_unpack_kernel, bw=bw),
        grid=(W // bw,),
        in_specs=[pl.BlockSpec((bw,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bw * WORD_BITS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((W * WORD_BITS,), jnp.bool_),
        interpret=interpret,
    )(words)


# ------------------------------------------------------------------- pack
def _pack_kernel(m_ref, o_ref, *, bw: int):
    bits = m_ref[...].reshape(bw, WORD_BITS).astype(jnp.uint32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (bw, WORD_BITS), 1)
    o_ref[...] = jnp.sum(bits << shifts, axis=1, dtype=jnp.uint32)


def bitset_pack(mask: jnp.ndarray, *, bw: int = 512, interpret: bool = False):
    """(n,) bool (n % 32 == 0) → (n/32,) uint32."""
    n = mask.shape[0]
    assert n % WORD_BITS == 0
    W = n // WORD_BITS
    bw = min(bw, W)
    while W % bw:
        bw //= 2
    return pl.pallas_call(
        functools.partial(_pack_kernel, bw=bw),
        grid=(W // bw,),
        in_specs=[pl.BlockSpec((bw * WORD_BITS,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((W,), jnp.uint32),
        interpret=interpret,
    )(mask)


# ----------------------------------------------------------------- lookup
def _lookup_kernel(w_ref, id_ref, o_ref):
    # w_ref: VMEM-resident bitset
    o_ref[...] = lookup_reference(w_ref[...], id_ref[...])


def bitset_lookup(
    words: jnp.ndarray,
    ids: jnp.ndarray,
    *,
    bi: int = 2048,
    interpret: bool = False,
):
    """Membership test for arbitrary int32 ids. Negative or out-of-range ids
    are masked to ``False`` in-kernel (an earlier version clipped them onto
    word 0 / the last word, silently aliasing adversarial ids onto real
    bits). The bitset stays VMEM-resident across id tiles — per-shard
    bitsets are ≤ a few MB at production shard counts."""
    n = ids.shape[0]
    bi = min(bi, n)
    while n % bi:
        bi //= 2
    return pl.pallas_call(
        _lookup_kernel,
        grid=(n // bi,),
        in_specs=[
            pl.BlockSpec(words.shape, lambda i: (0,)),
            pl.BlockSpec((bi,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bi,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.bool_),
        interpret=interpret,
    )(words, ids)


# --------------------------------------------------------- candidate filter
def _cand_filter_kernel(w_ref, id_ref, lab_ref, rok_ref, o_ref, *, child_label):
    """Fused MatchSTwig step-2: per edge, dst-label equality ∧ binding-bit
    test ∧ root-candidacy — one VMEM pass instead of three XLA ops."""
    ids = id_ref[...]
    o_ref[...] = (
        rok_ref[...]
        & (lab_ref[...] == child_label)
        & lookup_reference(w_ref[...], ids)
    )


def candidate_filter(
    words: jnp.ndarray,       # (W,) uint32 binding bitset (VMEM-resident)
    dst_ids: jnp.ndarray,     # (E,) int32 edge destination ids
    dst_labels: jnp.ndarray,  # (E,) int32 destination labels
    root_ok: jnp.ndarray,     # (E,) bool root-candidacy per edge
    child_label: int,
    *,
    bi: int = 2048,
    interpret: bool = False,
) -> jnp.ndarray:
    n = dst_ids.shape[0]
    bi = min(bi, n)
    while n % bi:
        bi //= 2
    return pl.pallas_call(
        functools.partial(_cand_filter_kernel, child_label=child_label),
        grid=(n // bi,),
        in_specs=[
            pl.BlockSpec(words.shape, lambda i: (0,)),
            pl.BlockSpec((bi,), lambda i: (i,)),
            pl.BlockSpec((bi,), lambda i: (i,)),
            pl.BlockSpec((bi,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bi,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.bool_),
        interpret=interpret,
    )(words, dst_ids, dst_labels, root_ok)
