"""Jitted wrappers choosing Pallas-on-TPU / jnp elsewhere."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bitset import bitset as k
from repro.kernels.bitset import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def unpack(words, *, use_pallas=None, interpret=False):
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return k.bitset_unpack(words, interpret=interpret)
    return ref.unpack_reference(words)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def pack(mask, *, use_pallas=None, interpret=False):
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return k.bitset_pack(mask, interpret=interpret)
    return ref.pack_reference(mask)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def lookup(words, ids, *, use_pallas=None, interpret=False):
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return k.bitset_lookup(words, ids, interpret=interpret)
    return ref.lookup_reference(words, ids)
