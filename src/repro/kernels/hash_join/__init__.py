"""Sort-merge join probe kernel. `ref` (pure jnp, light) loads eagerly; the
Pallas kernel module only loads when `hash_join_probe` is first touched."""
from repro.kernels.hash_join import ref

__all__ = ["hash_join_probe", "ref"]


def __getattr__(name):  # PEP 562 lazy import of the Pallas kernel
    if name == "hash_join_probe":
        from repro.kernels.hash_join.hash_join import hash_join_probe as fn

        globals()["hash_join_probe"] = fn  # cache: bypass __getattr__ next time
        return fn
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
