"""Pallas TPU kernel for the sort-merge join probe (paper §4.2 step 3).

Build side resident in VMEM: sorted hashed keys, their validity, and the
(narrow) exact key columns — join tables are the paper's memory-bounded
pipeline blocks. Probe side tiled over the grid; per probe key a fully
vectorized binary search (static ceil(log2(capA)) compare/select steps)
yields the run start, then a static ``dup_cap`` window is verified: hash
equality ∧ build/probe validity ∧ exact key-column equality, all in-kernel.
Only the wide payload gather stays in XLA (it would blow VMEM).

Oracle: `repro.kernels.hash_join.ref.probe_reference` (the code previously
inlined in `repro.core.join.sort_merge_join`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(
    ka_ref, akey_ref, avalid_ref, kb_ref, bkey_ref, bvalid_ref,
    hit_ref, idx_ref, *, cap_a: int, steps: int, dup_cap: int, nk: int,
):
    ka = ka_ref[...]             # (capA,)
    kb = kb_ref[...]             # (BB,)
    bb = kb.shape[0]

    lo = jnp.zeros((bb,), jnp.int32)
    hi = jnp.full((bb,), cap_a, jnp.int32)
    for _ in range(steps):       # static unroll: ceil(log2(capA+1)) steps
        # `active` guards converged lanes: once lo == hi an unguarded
        # extra step would overshoot past the true lower bound
        active = lo < hi
        # >> 1, not // 2: lo/hi are non-negative and a bare Python divisor
        # becomes an int64 scalar operand under x64
        mid = (lo + hi) >> 1
        vals = jnp.take(ka, jnp.minimum(mid, cap_a - 1))
        go_right = active & (vals < kb)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)

    probe = lo[:, None] + jax.lax.broadcasted_iota(jnp.int32, (bb, dup_cap), 1)
    in_range = probe < cap_a
    pc = jnp.minimum(probe, cap_a - 1)
    hit = (
        in_range
        & (jnp.take(ka, pc) == kb[:, None])
        & bvalid_ref[...][:, None]
        & jnp.take(avalid_ref[...], pc)
    )
    for j in range(nk):          # exact-key verification (hash collisions)
        hit &= jnp.take(akey_ref[...][:, j], pc) == bkey_ref[...][:, j][:, None]
    hit_ref[...] = hit
    idx_ref[...] = pc


def hash_join_probe(
    ka_sorted: jnp.ndarray,
    a_keys: jnp.ndarray,
    a_valid: jnp.ndarray,
    kb: jnp.ndarray,
    b_keys: jnp.ndarray,
    b_valid: jnp.ndarray,
    *,
    dup_cap: int,
    bb: int = 2048,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused lower-bound + window + exact verification; see `ref`."""
    cap_a = ka_sorted.shape[0]
    nk = a_keys.shape[-1]
    n = kb.shape[0]
    bb = min(bb, n)
    while n % bb:
        bb //= 2
    # the search interval is [0, cap_a] — cap_a + 1 states, so cap_a powers
    # of two need bit_length(cap_a) steps, not bit_length(cap_a - 1)
    steps = max(1, cap_a.bit_length())
    return pl.pallas_call(
        functools.partial(
            _probe_kernel, cap_a=cap_a, steps=steps, dup_cap=dup_cap, nk=nk
        ),
        grid=(n // bb,),
        in_specs=[
            pl.BlockSpec((cap_a,), lambda i: (0,)),
            pl.BlockSpec((cap_a, nk), lambda i: (0, 0)),
            pl.BlockSpec((cap_a,), lambda i: (0,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb, nk), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, dup_cap), lambda i: (i, 0)),
            pl.BlockSpec((bb, dup_cap), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, dup_cap), jnp.bool_),
            jax.ShapeDtypeStruct((n, dup_cap), jnp.int32),
        ],
        interpret=interpret,
    )(ka_sorted, a_keys, a_valid, kb, b_keys, b_valid)
