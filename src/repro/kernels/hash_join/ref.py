"""Pure-jnp oracle for the fused sort-merge join probe.

Factored out of `repro.core.join.sort_merge_join`: given the build side
sorted by hashed key, find each probe key's run start (lower bound), expand
a static ``dup_cap`` window, and verify hash equality, row validity AND
exact key-column equality — the full probe, so hash collisions are resolved
here and the caller only gathers payloads for true hits.
"""
from __future__ import annotations

import jax.numpy as jnp


def probe_reference(
    ka_sorted: jnp.ndarray,   # (capA,) uint32 ascending hashed keys
    a_keys: jnp.ndarray,      # (capA, nk) int32 key columns, same order
    a_valid: jnp.ndarray,     # (capA,) bool, same order
    kb: jnp.ndarray,          # (capB,) uint32 hashed probe keys
    b_keys: jnp.ndarray,      # (capB, nk) int32 probe key columns
    b_valid: jnp.ndarray,     # (capB,) bool
    *,
    dup_cap: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``hit (capB, dup_cap)`` bool (exact-verified) and
    ``idx (capB, dup_cap)`` int32 positions into the sorted build side."""
    cap_a = ka_sorted.shape[0]
    lo = jnp.searchsorted(ka_sorted, kb, side="left").astype(jnp.int32)
    probe = lo[:, None] + jnp.arange(dup_cap, dtype=jnp.int32)[None, :]
    in_range = probe < cap_a
    pc = jnp.minimum(probe, cap_a - 1)
    hit = (
        in_range
        & (ka_sorted[pc] == kb[:, None])
        & b_valid[:, None]
        & a_valid[pc]
    )
    for j in range(a_keys.shape[-1]):  # exact-key verification (collisions)
        hit &= a_keys[pc, j] == b_keys[:, j][:, None]
    return hit, pc
