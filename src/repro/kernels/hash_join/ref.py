"""Pure-jnp oracle for the fused sort-merge join probe.

Factored out of `repro.core.join.sort_merge_join`: given the build side
sorted by hashed key, find each probe key's run start (lower bound), expand
a static ``dup_cap`` window, and verify hash equality, row validity AND
exact key-column equality — the full probe, so hash collisions are resolved
here and the caller only gathers payloads for true hits.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp


def _lower_bound_i32(ka_sorted: jnp.ndarray, kb: jnp.ndarray) -> jnp.ndarray:
    """``searchsorted(ka, kb, side='left')`` as an unrolled branchless binary
    search in pure int32 — ``jnp.searchsorted`` runs its index arithmetic in
    int64 under x64, which the staticcheck dtype-width contract forbids.
    Mirrors the Pallas ``join_probe`` kernel loop."""
    cap = ka_sorted.shape[0]
    lo = jnp.zeros(kb.shape, jnp.int32)
    hi = jnp.full(kb.shape, cap, jnp.int32)
    for _ in range(max(1, int(cap).bit_length())):
        active = lo < hi
        mid = (lo + hi) >> 1
        go_right = active & (jnp.take(ka_sorted, mid, mode="clip") < kb)
        lo = jnp.where(go_right, mid + np.int32(1), lo)
        hi = jnp.where(active & ~go_right, mid, hi)
    return lo


def probe_reference(
    ka_sorted: jnp.ndarray,   # (capA,) uint32 ascending hashed keys
    a_keys: jnp.ndarray,      # (capA, nk) int32 key columns, same order
    a_valid: jnp.ndarray,     # (capA,) bool, same order
    kb: jnp.ndarray,          # (capB,) uint32 hashed probe keys
    b_keys: jnp.ndarray,      # (capB, nk) int32 probe key columns
    b_valid: jnp.ndarray,     # (capB,) bool
    *,
    dup_cap: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``hit (capB, dup_cap)`` bool (exact-verified) and
    ``idx (capB, dup_cap)`` int32 positions into the sorted build side."""
    cap_a = ka_sorted.shape[0]
    lo = _lower_bound_i32(ka_sorted, kb)
    probe = lo[:, None] + jnp.arange(dup_cap, dtype=jnp.int32)[None, :]
    in_range = probe < np.int32(cap_a)
    pc = jnp.minimum(probe, np.int32(cap_a - 1))
    hit = (
        in_range
        & (ka_sorted[pc] == kb[:, None])
        & b_valid[:, None]
        & a_valid[pc]
    )
    for j in range(a_keys.shape[-1]):  # exact-key verification (collisions)
        # static column slice + take: mixed advanced/scalar indexing
        # (a_keys[pc, j]) widens the scalar index to int64 under x64
        hit &= jnp.take(a_keys[:, j], pc, mode="clip") == b_keys[:, j][:, None]
    return hit, pc
