"""Pure-jnp oracle for the fused STwig expansion (MatchSTwig steps 2-3).

Factored out of `repro.core.match.match_stwig_shard`'s per-child loop so the
logic exists once, behind the `Kernels` registry: per-child candidate-edge
filtering (dst-label equality ∧ binding-bit membership ∧ root candidacy)
followed by per-root compaction into fixed-capacity candidate lists.

Compaction is scatter-free. Per child the pass builds two edge-length
arrays — ``ic`` (inclusive cumsum of the survivor mask) and ``nxt``
(reverse cummin of survivor edge index, i.e. the first surviving edge at or
after each position, sentinel ``E``) — then, because the CSR ``indptr``
gives each root's edge segment ``[lo, hi)`` directly:

  * exact counts come from two boundary gathers into ``ic``
    (``ic[hi-1] - ic[lo-1]``), and
  * the candidate list comes from a ``child_cap``-step gather chain through
    ``nxt``: ``e0 = nxt[lo]``, ``e_{p+1} = nxt[e_p + 1]`` — each root's
    first ``child_cap`` survivors in edge order, no sort, no scatter.

All ``k`` children share the one pass structure (the mask/cumsum/cummin
stage is per child but nothing is re-ranked per root), which is what makes
this the fast CPU hot path: the old formulation scattered every edge into a
``(cap+1, child_cap)`` table per child and re-ranked via segment sums.

Contract (shared with the Pallas kernel):
  * ``cand[c, r, p]`` is the ``p``-th (in edge order) surviving destination
    of root row ``r`` for child ``c``; unused slots hold the ghost id
    ``n_total``. Row ``cap`` is a write-off row for padded edges.
  * ``cnt[c, r]`` is the EXACT per-root candidate count — it may exceed
    ``child_cap`` (the caller uses that to flag overflow); only the first
    ``child_cap`` candidates are materialized.
  * ``indptr`` is ``(cap+2,)`` int32 CSR bounds over the edge arrays:
    root ``r``'s edges live at ``[indptr[r], indptr[r+1])`` and the ghost
    row ``cap`` owns the pad tail ``[indptr[cap], indptr[cap+1] == E)``.
    Edges NOT grouped by root violate the contract (the engine's
    `ShardGraph` arrays are CSR by construction).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.bitset.ref import lookup_reference


def stwig_expand_reference(
    words_k: jnp.ndarray,     # (k, W) uint32 binding bitsets, row per child
    dst_ids: jnp.ndarray,     # (E,) int32 edge destination global ids
    dst_labels: jnp.ndarray,  # (E,) int32 destination labels
    indptr: jnp.ndarray,      # (cap+2,) int32 CSR bounds incl. pad tail
    root_ok: jnp.ndarray,     # (E,) bool root-candidacy per edge
    *,
    child_labels: tuple[int, ...],
    child_bound: tuple[bool, ...],
    child_cap: int,
    cap: int,
    n_total: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``cand (k, cap+1, child_cap)`` and ``cnt (k, cap)``."""
    k = len(child_labels)
    C = child_cap
    E = dst_ids.shape[0]
    # np.int32 literals: a bare Python int branch arrives as an int64
    # scalar under x64 (staticcheck jaxpr-dtype-width)
    iE = np.int32(E)
    lo = indptr[:-1]  # (cap+1,)
    hi = indptr[1:]
    slots = jnp.arange(C, dtype=jnp.int32)
    edge_idx = jnp.arange(E, dtype=jnp.int32)
    cands, cnts = [], []
    for i in range(k):
        m = root_ok & (dst_labels == np.int32(child_labels[i]))
        if child_bound[i]:
            m &= lookup_reference(words_k[i], dst_ids)
        ic = jnp.cumsum(m.astype(jnp.int32))
        nxt = jax.lax.associative_scan(
            jnp.minimum, jnp.where(m, edge_idx, iE), reverse=True
        )
        # nxt_pad[E] = E so the chain saturates at the sentinel
        nxt_pad = jnp.concatenate([nxt, jnp.full((1,), iE, jnp.int32)])
        base = jnp.where(
            lo > 0, jnp.take(ic, jnp.maximum(lo - 1, 0), mode="clip"),
            np.int32(0),
        )
        last = jnp.where(
            hi > 0, jnp.take(ic, jnp.maximum(hi - 1, 0), mode="clip"),
            np.int32(0),
        )
        cnt = last - base  # (cap+1,) exact counts
        e = jnp.take(nxt_pad, jnp.minimum(lo, iE), mode="clip")
        es = [e]
        for _ in range(C - 1):
            e = jnp.take(nxt_pad, jnp.minimum(e + np.int32(1), iE), mode="clip")
            es.append(e)
        ee = jnp.stack(es, axis=1)  # (cap+1, C)
        c_i = jnp.where(
            slots[None, :] < cnt[:, None],
            jnp.take(dst_ids, jnp.minimum(ee, iE - np.int32(1)), mode="clip"),
            np.int32(n_total),
        )
        cands.append(c_i)
        cnts.append(cnt[:cap])
    return jnp.stack(cands), jnp.stack(cnts)
