"""Pure-jnp oracle for the fused STwig expansion (MatchSTwig steps 2-3).

Factored out of `repro.core.match.match_stwig_shard`'s per-child loop so the
logic exists once, behind the `Kernels` registry: per-child candidate-edge
filtering (dst-label equality ∧ binding-bit membership ∧ root candidacy)
followed by per-root compaction into fixed-capacity candidate lists.

Contract (shared with the Pallas kernel):
  * ``cand[c, r, p]`` is the ``p``-th (in edge order) surviving destination
    of root row ``r`` for child ``c``; unused slots hold the ghost id
    ``n_total``. Row ``cap`` is a write-off row for padded edges.
  * ``cnt[c, r]`` is the EXACT per-root candidate count — it may exceed
    ``child_cap`` (the caller uses that to flag overflow); only the first
    ``child_cap`` candidates are materialized.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.bitset.ref import lookup_reference


def _exclusive_cumsum(m: jnp.ndarray) -> jnp.ndarray:
    c = jnp.cumsum(m.astype(jnp.int32))
    return c - m.astype(jnp.int32)


def stwig_expand_reference(
    words_k: jnp.ndarray,     # (k, W) uint32 binding bitsets, row per child
    dst_ids: jnp.ndarray,     # (E,) int32 edge destination global ids
    dst_labels: jnp.ndarray,  # (E,) int32 destination labels
    edge_src: jnp.ndarray,    # (E,) int32 local source rows, pad = cap
    seg_start: jnp.ndarray,   # (E,) int32 edge index of src's first edge
    root_ok: jnp.ndarray,     # (E,) bool root-candidacy per edge
    *,
    child_labels: tuple[int, ...],
    child_bound: tuple[bool, ...],
    child_cap: int,
    cap: int,
    n_total: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``cand (k, cap+1, child_cap)`` and ``cnt (k, cap)``."""
    k = len(child_labels)
    C = child_cap
    cands, cnts = [], []
    for i in range(k):
        m = root_ok & (dst_labels == child_labels[i])
        if child_bound[i]:
            m &= lookup_reference(words_k[i], dst_ids)
        ecs = _exclusive_cumsum(m)
        pos = ecs - jnp.take(ecs, seg_start)
        c_i = jnp.full((cap + 1, C), n_total, dtype=jnp.int32)
        # np.int32 literals: a bare Python int branch arrives as an int64
        # scalar under x64 (staticcheck jaxpr-dtype-width)
        src = jnp.where(m, edge_src, np.int32(cap))
        p = jnp.where(m, pos, np.int32(C))
        c_i = c_i.at[src, p].set(dst_ids, mode="drop")
        n_i = jax.ops.segment_sum(
            m.astype(jnp.int32), edge_src, num_segments=cap + 1
        )[:cap]
        cands.append(c_i)
        cnts.append(n_i)
    return jnp.stack(cands), jnp.stack(cnts)
