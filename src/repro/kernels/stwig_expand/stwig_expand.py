"""Pallas TPU kernel fusing MatchSTwig steps 2-3 (paper Algorithm 1).

One pass over the shard's edge array does, per child of the STwig:
  * the candidate filter — dst-label equality ∧ binding-bit membership
    (bitsets VMEM-resident, out-of-range ids masked False) ∧ root candidacy;
  * per-root compaction — surviving destinations are appended to their
    source row's fixed-capacity candidate list.

The filter is fully vectorized per edge tile; the compaction walks the tile
serially with scalar dynamic stores (TPU supports single-element dynamic
load/store; XLA has no scatter-append at all, which is why the jnp oracle
needs a cumsum + segment-rank detour). The grid is sequential over edge
tiles and the outputs are revisited with a constant index map, so the
running per-root counts carry across tiles for free.

Oracle: `repro.kernels.stwig_expand.ref.stwig_expand_reference` (the code
previously inlined in `repro.core.match`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitset.ref import lookup_reference


def _expand_kernel(
    w_ref,      # (k, W) uint32 binding bitsets
    dst_ref,    # (BE,) int32 destination ids
    lab_ref,    # (BE,) int32 destination labels
    src_ref,    # (BE,) int32 local source rows
    rok_ref,    # (BE,) bool root-candidacy
    cand_ref,   # (k, cap+1, C) int32 out — revisited every tile
    cnt_ref,    # (k, cap+1) int32 out — revisited every tile
    *,
    child_labels: tuple[int, ...],
    child_bound: tuple[bool, ...],
    C: int,
    n_total: int,
    be: int,
):
    k = len(child_labels)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        cand_ref[...] = jnp.full(cand_ref.shape, n_total, jnp.int32)
        cnt_ref[...] = jnp.zeros(cnt_ref.shape, jnp.int32)

    ids = dst_ref[...]
    labs = lab_ref[...]
    rok = rok_ref[...]
    words = w_ref[...]

    # ---- vectorized per-child filter over the tile ------------------------
    masks = []
    for c in range(k):
        m = rok & (labs == child_labels[c])
        if child_bound[c]:
            m &= lookup_reference(words[c], ids)
        masks.append(m)
    mk = jnp.stack(masks)  # (k, BE)

    # ---- serial per-root compaction (scalar dynamic stores) ---------------
    def body(e, _):
        s = src_ref[e]
        d = ids[e]
        for c in range(k):

            @pl.when(mk[c, e])
            def _append(c=c):
                p = cnt_ref[c, s]

                @pl.when(p < C)
                def _store():
                    cand_ref[c, s, p] = d

                # the count keeps growing past C: callers detect overflow
                cnt_ref[c, s] = p + 1

        return 0

    jax.lax.fori_loop(0, be, body, 0)


def stwig_expand(
    words_k: jnp.ndarray,     # (k, W) uint32
    dst_ids: jnp.ndarray,     # (E,) int32
    dst_labels: jnp.ndarray,  # (E,) int32
    edge_src: jnp.ndarray,    # (E,) int32, pad = cap (masked out via root_ok)
    seg_start: jnp.ndarray,   # (E,) int32 — unused here (the sequential walk
    #                           carries counts); kept for oracle parity
    root_ok: jnp.ndarray,     # (E,) bool
    *,
    child_labels: tuple[int, ...],
    child_bound: tuple[bool, ...],
    child_cap: int,
    cap: int,
    n_total: int,
    be: int = 2048,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused filter + compaction: ``cand (k, cap+1, C)``, ``cnt (k, cap)``."""
    del seg_start
    k = len(child_labels)
    assert k >= 1 and words_k.shape[0] == k
    E = dst_ids.shape[0]
    be = min(be, E)
    while E % be:
        be //= 2
    cand, cnt = pl.pallas_call(
        functools.partial(
            _expand_kernel,
            child_labels=tuple(child_labels),
            child_bound=tuple(child_bound),
            C=child_cap,
            n_total=n_total,
            be=be,
        ),
        grid=(E // be,),
        in_specs=[
            pl.BlockSpec(words_k.shape, lambda i: (0, 0)),
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((k, cap + 1, child_cap), lambda i: (0, 0, 0)),
            pl.BlockSpec((k, cap + 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, cap + 1, child_cap), jnp.int32),
            jax.ShapeDtypeStruct((k, cap + 1), jnp.int32),
        ],
        interpret=interpret,
    )(words_k, dst_ids, dst_labels, edge_src, root_ok)
    return cand, cnt[:, :cap]
