"""Pallas TPU kernels fusing MatchSTwig steps 2-3 (paper Algorithm 1).

Three short kernels replace the old single kernel's serial edge walk with
per-tile vectorized compaction:

  * **mask+ics** — forward pass over edge tiles: the per-child candidate
    filter (dst-label equality ∧ binding-bit membership, bitsets
    VMEM-resident, out-of-range ids masked False ∧ root candidacy), then an
    in-tile log-doubling inclusive prefix sum of the stacked ``(k, be)``
    mask. A ``(k, 1)`` carry output revisited with a constant index map
    threads the running totals across the sequential grid, so the prefix
    sums are global and overflow-past-``child_cap`` semantics are
    unchanged — counts keep growing past the materialized capacity.
  * **nxt** — the same grid traversed in REVERSE via the block index map:
    an in-tile log-doubling suffix-min of the survivor edge index
    (sentinel = padded length) plus a carried minimum gives, per position,
    the first surviving edge at or after it.
  * **emit** — a grid over root tiles with the full ``(k, epad)`` prefix
    arrays VMEM-resident: per root, exact counts from two boundary gathers
    into the prefix sums, the candidate list from a ``child_cap``-step
    vectorized gather chain through ``nxt``, and one whole-block store per
    output. No scalar dynamic stores anywhere.

Edge arrays are padded to a tile multiple (pad dst = ghost ``n_total``,
``root_ok`` = False), so any edge count — including odd/prime ``E`` —
keeps full-width tiles; the old fallback halved the tile size until it
divided ``E``, collapsing to 1-edge tiles for prime ``E``. Root tiles are
padded the same way (empty segments at the pad sentinel) and sliced off
the outputs.

Oracle: `repro.kernels.stwig_expand.ref.stwig_expand_reference` (same
scatter-free formulation in pure jnp).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitset.ref import lookup_reference


def _mask_ics_kernel(
    w_ref,      # (k, W) uint32 binding bitsets
    dst_ref,    # (be,) int32 destination ids
    lab_ref,    # (be,) int32 destination labels
    rok_ref,    # (be,) bool root-candidacy
    mask_ref,   # (k, be) bool out — survivor mask
    ics_ref,    # (k, be) int32 out — global inclusive cumsum of the mask
    carry_ref,  # (k, 1) int32 — running totals, revisited every tile
    *,
    child_labels: tuple[int, ...],
    child_bound: tuple[bool, ...],
    be: int,
):
    k = len(child_labels)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry_ref[...] = jnp.zeros(carry_ref.shape, jnp.int32)

    ids = dst_ref[...]
    labs = lab_ref[...]
    rok = rok_ref[...]
    words = w_ref[...]
    masks = []
    for c in range(k):
        m = rok & (labs == np.int32(child_labels[c]))
        if child_bound[c]:
            m &= lookup_reference(words[c], ids)
        masks.append(m)
    mk = jnp.stack(masks)                       # (k, be)
    x = mk.astype(jnp.int32)
    s = 1
    while s < be:                               # log-doubling inclusive scan
        x = x + jnp.pad(x, ((0, 0), (s, 0)))[:, :be]
        s *= 2
    x = x + carry_ref[...]
    mask_ref[...] = mk
    ics_ref[...] = x
    carry_ref[...] = x[:, -1:]


def _nxt_kernel(mask_ref, nxt_ref, carry_ref, *, k, be, n_tiles, epad):
    """Reverse traversal (index map runs tiles last-to-first): per position,
    the smallest surviving global edge index at or after it (sentinel
    ``epad``), via in-tile suffix-min + carried minimum."""
    t = pl.program_id(0)                        # 0 => LAST tile

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = jnp.full(carry_ref.shape, np.int32(epad), jnp.int32)

    tile = (n_tiles - 1) - t                    # actual tile index
    gidx = np.int32(be) * tile.astype(jnp.int32) + jax.lax.broadcasted_iota(
        jnp.int32, (k, be), 1
    )
    y = jnp.where(mask_ref[...], gidx, np.int32(epad))
    s = 1
    while s < be:
        y = jnp.minimum(
            y,
            jnp.pad(y, ((0, 0), (0, s)), constant_values=np.int32(epad))[:, s:],
        )
        s *= 2
    y = jnp.minimum(y, carry_ref[...])
    nxt_ref[...] = y
    carry_ref[...] = y[:, :1]


def _emit_kernel(
    lo_ref,    # (rt,) int32 segment starts
    hi_ref,    # (rt,) int32 segment ends
    ics_ref,   # (k, epad) int32 — whole array resident
    nxt_ref,   # (k, epad) int32 — whole array resident
    dst_ref,   # (epad,) int32 — whole array resident
    cand_ref,  # (k, rt, C) int32 out
    cnt_ref,   # (k, rt) int32 out
    *,
    k: int,
    C: int,
    n_total: int,
    epad: int,
):
    lo = lo_ref[...]
    hi = hi_ref[...]
    iep = np.int32(epad)
    cands, cnts = [], []
    for c in range(k):
        ic = ics_ref[c]
        nx = nxt_ref[c]
        base = jnp.where(
            lo > 0, jnp.take(ic, jnp.maximum(lo - 1, 0), mode="clip"),
            np.int32(0),
        )
        last = jnp.where(
            hi > 0, jnp.take(ic, jnp.maximum(hi - 1, 0), mode="clip"),
            np.int32(0),
        )
        cnt = last - base                       # (rt,) exact counts
        e = jnp.where(
            lo < iep,
            jnp.take(nx, jnp.minimum(lo, iep - np.int32(1)), mode="clip"),
            iep,
        )
        es = [e]
        for _ in range(C - 1):
            q = e + np.int32(1)
            e = jnp.where(
                q < iep,
                jnp.take(nx, jnp.minimum(q, iep - np.int32(1)), mode="clip"),
                iep,
            )
            es.append(e)
        ee = jnp.stack(es, axis=1)              # (rt, C)
        slots = jax.lax.broadcasted_iota(jnp.int32, ee.shape, 1)
        cv = jnp.where(
            slots < cnt[:, None],
            jnp.take(dst_ref[...], jnp.minimum(ee, iep - np.int32(1)),
                     mode="clip"),
            np.int32(n_total),
        )
        cands.append(cv)
        cnts.append(cnt)
    cand_ref[...] = jnp.stack(cands)
    cnt_ref[...] = jnp.stack(cnts)


def stwig_expand(
    words_k: jnp.ndarray,     # (k, W) uint32
    dst_ids: jnp.ndarray,     # (E,) int32
    dst_labels: jnp.ndarray,  # (E,) int32
    indptr: jnp.ndarray,      # (cap+2,) int32 CSR bounds incl. pad tail
    root_ok: jnp.ndarray,     # (E,) bool
    *,
    child_labels: tuple[int, ...],
    child_bound: tuple[bool, ...],
    child_cap: int,
    cap: int,
    n_total: int,
    be: int = 2048,
    rt: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused filter + compaction: ``cand (k, cap+1, C)``, ``cnt (k, cap)``."""
    k = len(child_labels)
    assert k >= 1 and words_k.shape[0] == k
    C = child_cap
    E = dst_ids.shape[0]
    be = min(be, max(E, 1))
    n_tiles = -(-E // be) if E else 1
    epad = n_tiles * be
    pad_e = epad - E
    if pad_e:  # full-width tiles for any E (prime E included)
        dst_ids = jnp.pad(dst_ids, (0, pad_e), constant_values=np.int32(n_total))
        dst_labels = jnp.pad(dst_labels, (0, pad_e))
        root_ok = jnp.pad(root_ok, (0, pad_e))

    mask, ics, _ = pl.pallas_call(
        functools.partial(
            _mask_ics_kernel,
            child_labels=tuple(child_labels),
            child_bound=tuple(child_bound),
            be=be,
        ),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(words_k.shape, lambda i: (0, 0)),
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((k, be), lambda i: (0, i)),
            pl.BlockSpec((k, be), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, epad), jnp.bool_),
            jax.ShapeDtypeStruct((k, epad), jnp.int32),
            jax.ShapeDtypeStruct((k, 1), jnp.int32),
        ],
        interpret=interpret,
    )(words_k, dst_ids, dst_labels, root_ok)

    nxt, _ = pl.pallas_call(
        functools.partial(_nxt_kernel, k=k, be=be, n_tiles=n_tiles, epad=epad),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((k, be), lambda i, n=n_tiles: (0, n - 1 - i))],
        out_specs=[
            pl.BlockSpec((k, be), lambda i, n=n_tiles: (0, n - 1 - i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, epad), jnp.int32),
            jax.ShapeDtypeStruct((k, 1), jnp.int32),
        ],
        interpret=interpret,
    )(mask)

    # per-root CSR segment bounds; pad roots get the empty segment
    # [epad, epad) so they emit zero counts and all-ghost rows
    lo = indptr[:-1]
    hi = indptr[1:]
    R = cap + 1
    rt = min(rt, R)
    r_tiles = -(-R // rt)
    rpad = r_tiles * rt - R
    if rpad:
        lo = jnp.pad(lo, (0, rpad), constant_values=np.int32(epad))
        hi = jnp.pad(hi, (0, rpad), constant_values=np.int32(epad))

    cand, cnt = pl.pallas_call(
        functools.partial(_emit_kernel, k=k, C=C, n_total=n_total, epad=epad),
        grid=(r_tiles,),
        in_specs=[
            pl.BlockSpec((rt,), lambda i: (i,)),
            pl.BlockSpec((rt,), lambda i: (i,)),
            pl.BlockSpec((k, epad), lambda i: (0, 0)),
            pl.BlockSpec((k, epad), lambda i: (0, 0)),
            pl.BlockSpec((epad,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((k, rt, C), lambda i: (0, i, 0)),
            pl.BlockSpec((k, rt), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, r_tiles * rt, C), jnp.int32),
            jax.ShapeDtypeStruct((k, r_tiles * rt), jnp.int32),
        ],
        interpret=interpret,
    )(lo, hi, ics, nxt, dst_ids)
    return cand[:, :cap + 1], cnt[:, :cap]
