"""Fused STwig expansion kernel. `ref` (pure jnp, light) loads eagerly; the
Pallas kernel module only loads when `stwig_expand` is first touched."""
from repro.kernels.stwig_expand import ref

__all__ = ["stwig_expand", "ref"]


def __getattr__(name):  # PEP 562 lazy import of the Pallas kernel
    if name == "stwig_expand":
        from repro.kernels.stwig_expand.stwig_expand import stwig_expand as fn

        # rebind over the submodule attribute the import machinery just set
        # on this package, so later lookups get the function, not the module
        globals()["stwig_expand"] = fn
        return fn
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
