from repro.kernels.segment_mp.segment_mp import segment_mp, segment_mp_partials
from repro.kernels.segment_mp import ops, ref

__all__ = ["segment_mp", "segment_mp_partials", "ops", "ref"]
