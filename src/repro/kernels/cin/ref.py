"""Oracle: the materialized CIN layer (matches models/recsys._cin)."""
from __future__ import annotations

import jax.numpy as jnp


def cin_layer_reference(xk: jnp.ndarray, x0: jnp.ndarray, w: jnp.ndarray):
    B, H, d = xk.shape
    z = jnp.einsum("bhd,bmd->bhmd", xk, x0).reshape(B, -1, d)
    return jnp.einsum("bzd,zh->bhd", z, w)
