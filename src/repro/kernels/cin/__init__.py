from repro.kernels.cin.cin import cin_layer
from repro.kernels.cin import ops, ref

__all__ = ["cin_layer", "ops", "ref"]
