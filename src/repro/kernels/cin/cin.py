"""Pallas TPU kernel: fused xDeepFM CIN layer (arXiv:1803.05170).

One CIN layer is x_k[b,h',d] = Σ_{h,m} W[h·m, h'] · x_{k-1}[b,h,d] · x0[b,m,d].
The naive path materializes z = (B, H·m, d) (the outer product); the kernel
fuses the outer product with the W contraction per (batch-tile × d-tile), so
z only ever exists as a VMEM tile — the dominant HBM term drops from
O(B·H·m·d) to O(B·(H+m)·d).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cin_kernel(xk_ref, x0_ref, w_ref, o_ref, *, h: int, m: int):
    xk = xk_ref[0]                      # (H, BD)
    x0 = x0_ref[0]                      # (m, BD)
    w = w_ref[...]                      # (H*m, H')
    z = (xk[:, None, :] * x0[None, :, :]).reshape(h * m, -1)  # VMEM only
    o_ref[0] = jax.lax.dot_general(
        w, z, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)               # (H', BD)


def cin_layer(
    xk: jnp.ndarray,   # (B, H, d)
    x0: jnp.ndarray,   # (B, m, d)
    w: jnp.ndarray,    # (H*m, H')
    *,
    bd: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, d = xk.shape
    m = x0.shape[1]
    Hp = w.shape[1]
    bd = min(bd, d)
    while d % bd:
        bd //= 2
    out = pl.pallas_call(
        functools.partial(_cin_kernel, h=H, m=m),
        grid=(B, d // bd),
        in_specs=[
            pl.BlockSpec((1, H, bd), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, m, bd), lambda b, j: (b, 0, j)),
            pl.BlockSpec((H * m, Hp), lambda b, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hp, bd), lambda b, j: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, Hp, d), xk.dtype),
        interpret=interpret,
    )(xk, x0, w)
    return out
