"""Jitted wrapper for the fused CIN layer."""
from __future__ import annotations

import functools

import jax

from repro.kernels.cin import cin as k
from repro.kernels.cin import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def cin_layer(xk, x0, w, *, use_pallas=None, interpret=False):
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return k.cin_layer(xk, x0, w, interpret=interpret)
    return ref.cin_layer_reference(xk, x0, w)
