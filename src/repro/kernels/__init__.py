# OPTIONAL layer. Add <name>.py (or .cu) + ref.py ONLY for compute
# hot-spots the paper itself optimizes with a custom kernel. Matching
# hot-path kernels (bitset, stwig_expand, hash_join) are selected via the
# `Kernels` registry in `repro.core.backend` — register new backends there
# instead of adding per-package dispatch shims.
