"""Query-execution resilience: deadlines, memory budgets, bounded retry.

The paper's setting is a distributed memory cloud — shards stall, fetches
fail, memory is finite — but the engines' only failure policy used to be
blind capacity doubling. This module gives the facade and both engines a
shared vocabulary for *stopping well*:

  * `DegradeReason` — the typed "why" of a partial result
    (``MatchResult.complete=False`` alone says nothing about cause).
  * `QueryGuard` — per-query deadline + device-memory budget, checked at
    the natural host-side preemption points: between adaptive retries
    (`adaptive_run`) and between blocks in the streaming driver
    (`repro.core.stream.stream_blocks`). Jitted programs are never
    interrupted mid-flight; a guard trip returns the work already done.
  * `RetryPolicy` — replaces the bare doubling loop: seeded jittered
    backoff between retries, and a cap-growth ceiling so escalation
    provably stops *before* the doubled plan exceeds the memory budget
    rather than after an OOM. The ceiling comes from
    ``analysis/budgets.json`` (the ``retry`` section) and the per-cap
    byte estimates from the staticcheck cost model: the escalated join
    is abstractly traced (shapes only, nothing executes) and
    `costmodel.peak_bytes` scores the jaxpr.
  * `adaptive_run` — the one retry loop both engines and
    `CompiledQuery.run` now share.

This is the admission/eviction half of the future `QueryServer`
(ROADMAP item 1); the fault-injection half lives in `repro.runtime.chaos`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import enum
import random
import time
from typing import Callable

from repro.core.result import MatchResult, MatchStats

__all__ = [
    "DegradeReason",
    "QueryGuard",
    "RetryPolicy",
    "adaptive_run",
    "degraded_empty",
    "grow_caps",
    "join_cost_bytes",
    "plan_caps_bytes",
    "retry_ceiling_bytes",
]

# caps that adaptive escalation grows (and that MatchStats.final_caps
# reports) — everything else in a caps dict passes through untouched
GROWN_CAP_KEYS = ("child_cap", "join_rows_cap", "join_dup_cap")


class DegradeReason(str, enum.Enum):
    """Why a result came back partial. Stored in
    ``MatchStats.degrade_reason`` as the plain value string (members
    compare equal to their values, so ``reason == "deadline"`` works)."""

    DEADLINE = "deadline"                  # QueryGuard deadline expired
    BUDGET = "budget"                      # caller's memory budget exceeded
    OVERFLOW_CEILING = "overflow-ceiling"  # caps still overflow, growth capped
    SHARD_FAULT = "shard-fault"            # degraded to surviving shards

    def __str__(self) -> str:  # log lines print "deadline", not the repr
        return self.value


def grow_caps(caps: dict) -> dict:
    """One step of adaptive capacity growth (paper §4.2: block sizes are set
    by available memory; overflow doubles them and re-runs).

    Growth is plain doubling for every capacity, so retry ``r`` runs at
    ``2**r`` times the seed caps — geometric, bounded by the retry budget
    and by `RetryPolicy`'s byte ceiling. (An earlier version multiplied
    ``child_cap`` by ``2 * retries``, compounding super-exponentially and
    risking OOM before the retry budget was spent.)
    """
    caps = dict(caps)
    caps["child_cap"] = 2 * caps.get("child_cap", 8)
    caps["join_rows_cap"] = 2 * caps.get("join_rows_cap", 1 << 16)
    caps["join_dup_cap"] = 2 * caps.get("join_dup_cap", 64)
    return caps


# ----------------------------------------------------------- cost estimates

# (out_cap, dup_cap, width) -> peak bytes; abstract tracing is deterministic
# for fixed caps, so memoizing is safe (and keeps retry checks ~free)
_COST_CACHE: dict = {}

# canonical probe shape: two width-4 tables sharing one qnode, all labels
# equal (the worst case for the injectivity filters). The estimate only
# needs to be monotone in the caps and proportional to the real join's
# footprint; per-query widths vary by ±1-2 columns, the caps vary by 2**r.
_PROBE_WIDTH = 4


def join_cost_bytes(out_cap: int, dup_cap: int, width: int = _PROBE_WIDTH) -> float:
    """Peak resident bytes of one sort-merge join at the given capacities,
    from the staticcheck cost model's buffer-liveness scan over an
    abstract trace — shapes only, nothing executes, no device memory is
    touched."""
    key = (int(out_cap), int(dup_cap), int(width))
    hit = _COST_CACHE.get(key)
    if hit is not None:
        return hit
    import jax
    import jax.numpy as jnp

    from repro.analysis.staticcheck import costmodel
    from repro.core import join as join_lib

    w = max(2, int(width))
    sa = join_lib.Schema(qnodes=tuple(range(w)), qlabels=(0,) * w)
    sb = join_lib.Schema(
        qnodes=(w - 1,) + tuple(range(w, 2 * w - 1)), qlabels=(0,) * w
    )

    def table(cap):
        return join_lib.JoinTable(
            cols=jax.ShapeDtypeStruct((cap, w), jnp.int32),
            valid=jax.ShapeDtypeStruct((cap,), jnp.bool_),
            n_rows=jax.ShapeDtypeStruct((), jnp.int32),
            overflow=jax.ShapeDtypeStruct((), jnp.bool_),
        )

    jaxpr = jax.make_jaxpr(
        lambda a, b: join_lib.sort_merge_join(
            a, b, sa, sb, out_cap=int(out_cap), dup_cap=int(dup_cap)
        )[0]
    )(table(int(out_cap)), table(int(out_cap)))
    est = float(costmodel.peak_bytes(jaxpr))
    _COST_CACHE[key] = est
    return est


def plan_caps_bytes(caps: dict) -> float:
    """Byte estimate for a caps dict (the join dominates every other
    allocation by orders of magnitude, so it IS the estimate)."""
    return join_cost_bytes(
        caps.get("join_rows_cap", 1 << 16), caps.get("join_dup_cap", 64)
    )


def retry_ceiling_bytes(budgets: dict | None = None) -> float:
    """The cap-growth byte ceiling from ``analysis/budgets.json`` (the
    ``retry`` section). Missing file/section falls back to a conservative
    default rather than failing open with no ceiling at all."""
    if budgets is None:
        from repro.analysis.staticcheck import costmodel

        budgets = costmodel.load_budgets()
    retry = budgets.get("retry", {}) if isinstance(budgets, dict) else {}
    return float(retry.get("memory_ceiling_bytes", 4e9))


# ------------------------------------------------------------------ guard


@dataclasses.dataclass
class QueryGuard:
    """Per-query deadline and device-memory budget.

    Enforced cooperatively at host-side preemption points — never inside a
    jitted program — so a trip costs at most one in-flight block/retry: a
    deadline-bounded query returns within the deadline plus one unit of
    work, not after an unbounded run. ``clock`` is injectable for tests.
    """

    deadline_s: float | None = None
    memory_budget_bytes: float | None = None
    clock: Callable[[], float] = time.monotonic
    started_at: float | None = None

    def start(self) -> "QueryGuard":
        """Arm the deadline (idempotent — re-entering run/stream on the
        same guard keeps the original epoch, so one guard bounds a whole
        multi-call interaction)."""
        if self.started_at is None:
            self.started_at = self.clock()
        return self

    def elapsed_s(self) -> float:
        return 0.0 if self.started_at is None else self.clock() - self.started_at

    def remaining_s(self) -> float | None:
        if self.deadline_s is None:
            return None
        return self.deadline_s - self.elapsed_s()

    def check(self, planned_bytes: float | None = None) -> DegradeReason | None:
        """The preemption-point test: returns the degrade reason to stop
        with, or None to keep going. ``planned_bytes`` (when known) is the
        estimate for the work *about to be* scheduled."""
        rem = self.remaining_s()
        if rem is not None and rem <= 0:
            return DegradeReason.DEADLINE
        if (
            planned_bytes is not None
            and self.memory_budget_bytes is not None
            and planned_bytes > self.memory_budget_bytes
        ):
            return DegradeReason.BUDGET
        return None


# ------------------------------------------------------------------ policy


@dataclasses.dataclass
class RetryPolicy:
    """How adaptive escalation and fetch recovery retry.

    ``backoff(i)`` grows geometrically with deterministic, seeded jitter
    (two policies with equal seeds back off identically — chaos tests are
    reproducible). ``backoff_s`` defaults to 0 so plain adaptive runs keep
    their no-sleep behaviour; the sharded engine's fetch-retry loop uses
    ``fetch_backoff_s`` (`repro.runtime.chaos` injects the faults it
    recovers from). ``ceiling_bytes=None`` reads the checked-in ceiling
    from ``analysis/budgets.json``.
    """

    max_retries: int = 6
    backoff_s: float = 0.0
    fetch_retries: int = 3
    fetch_backoff_s: float = 0.01
    jitter: float = 0.5
    seed: int = 0
    ceiling_bytes: float | None = None

    def __post_init__(self):
        self._rng = random.Random(self.seed)

    def ceiling(self) -> float:
        if self.ceiling_bytes is not None:
            return float(self.ceiling_bytes)
        return retry_ceiling_bytes()

    def backoff(self, attempt: int, base_s: float | None = None) -> float:
        base = self.backoff_s if base_s is None else base_s
        return base * (2**attempt) * (1.0 + self.jitter * self._rng.random())

    def sleep(self, attempt: int, base_s: float | None = None) -> float:
        t = self.backoff(attempt, base_s)
        if t > 0:
            time.sleep(t)
        return t

    def next_caps(
        self, caps: dict, guard: QueryGuard | None = None
    ) -> tuple[dict | None, DegradeReason | None]:
        """One escalation step, or the typed reason there is none: the
        grown caps are costed BEFORE anything is planned or traced, so
        retry stops ahead of the OOM, not after it."""
        grown = grow_caps(caps)
        est = plan_caps_bytes(grown)
        if (
            guard is not None
            and guard.memory_budget_bytes is not None
            and est > guard.memory_budget_bytes
        ):
            return None, DegradeReason.BUDGET
        if est > self.ceiling():
            return None, DegradeReason.OVERFLOW_CEILING
        return grown, None


# -------------------------------------------------------------- retry loop


def _final_caps(caps: dict) -> dict:
    return {k: caps[k] for k in GROWN_CAP_KEYS if k in caps}


def mark_degraded(res: MatchResult, reason) -> MatchResult:
    """Stamp a typed degrade reason onto a result (idempotent; keeps any
    rows already produced — degraded ≠ empty)."""
    res.complete = False
    if res.stats.degrade_reason is None:
        res.stats.degrade_reason = str(
            reason.value if isinstance(reason, DegradeReason) else reason
        )
    return res


def degraded_empty(n_qnodes: int, backend: str, reason) -> MatchResult:
    """The result of refusing to run at all (pre-expired deadline, plan
    over budget at admission)."""
    import numpy as np

    stats = MatchStats(backend=backend)
    res = MatchResult(
        rows=np.zeros((0, n_qnodes), np.int64),
        n_matches=0,
        complete=False,
        stats=stats,
    )
    return mark_degraded(res, reason)


def adaptive_run(
    first: Callable[[], MatchResult],
    escalate: Callable[[dict], MatchResult],
    caps: dict,
    *,
    n_qnodes: int,
    backend: str,
    policy: RetryPolicy | None = None,
    guard: QueryGuard | None = None,
    adaptive: bool = True,
) -> MatchResult:
    """The shared adaptive loop behind `SubgraphMatcher.match`,
    `DistributedMatcher.match` and `CompiledQuery.run`.

    ``first`` runs the seed plan; ``escalate(caps)`` re-plans and re-runs
    at grown caps. Escalation stops on: success, a guard trip (deadline /
    budget), the policy's byte ceiling, the retry budget, or a result that
    already carries a degrade reason (a shard fault is not a capacity
    problem — growing caps would not help). With ``adaptive=False`` the
    first (possibly partial) result is returned — the paper's first-K
    semantics, not a degradation, so no reason is stamped.
    """
    policy = policy or RetryPolicy()
    caps = dict(caps)
    if guard is not None:
        guard.start()
        reason = guard.check(
            plan_caps_bytes(caps)
            if guard.memory_budget_bytes is not None
            else None
        )
        if reason is not None:
            res = degraded_empty(n_qnodes, backend, reason)
            res.stats.final_caps = _final_caps(caps)
            return res
    res = first()
    retries = 0
    while adaptive and not res.complete and res.stats.degrade_reason is None:
        if retries >= policy.max_retries:
            mark_degraded(res, DegradeReason.OVERFLOW_CEILING)
            break
        reason = guard.check() if guard is not None else None
        grown = None
        if reason is None:
            grown, reason = policy.next_caps(caps, guard)
        if reason is not None:
            mark_degraded(res, reason)
            break
        policy.sleep(retries)
        caps = grown
        retries += 1
        res = escalate(caps)
    res.stats.retries = retries
    res.stats.final_caps = _final_caps(caps)
    return res


@contextlib.contextmanager
def stage(stats: MatchStats, name: str):
    """Accumulate wall time of a named execution stage into
    ``stats.stage_times`` (re-entrant across blocks: times add up)."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        stats.stage_times[name] = (
            stats.stage_times.get(name, 0.0) + time.perf_counter() - t0
        )
