"""Continuous-batching `QueryServer`: many users, one device program.

`launch/serve.py` used to answer queries strictly one at a time — the
device sat idle between one query's block joins while the next query
waited whole. This module serves many in-flight queries from one
`GraphSession` the way vLLM-class LLM engines serve many decode streams
from one model: the PR-2 block-parameterized join step is the scheduler
quantum, and the scheduler round-robins those quanta across every
in-flight stream, admitting new queries as finished ones drain
(DESIGN.md §7).

Three ideas:

  * **Shape buckets.** A query's bucket is its executable identity —
    (STwig schemas, capacities, block size, kernels name), exactly the
    tuple that keys the session's `ExecutableCache`. Concurrent queries in
    one bucket share one traced executable: the first pays the jit trace,
    its bucket-mates run on cache hits. Admission prefers queries whose
    bucket is already live, so a bursty workload of similar queries
    converges onto warm executables instead of fanning traces out.
  * **Continuous batching.** One scheduler quantum = one block join of one
    in-flight query (`repro.core.stream.OpenStream.blocks`), or the
    run-once setup (exploration + Theorem-4 fetch) when a query is first
    admitted. Finished queries drain mid-loop and free their slot for the
    next queued query — the device never waits for a "batch" to close.
  * **Per-query degradation.** Every query carries its own `QueryGuard`
    (deadline armed at submission, so queue wait counts) and first-K
    budget. A trip degrades THAT query — its stream ends with a typed
    partial result — and its bucket-mates never notice; a per-query
    exception becomes a failed `QueryOutcome`, not a dead server. The
    only thing counted as global is an error escaping the scheduler loop
    itself (`ServerStats.global_degradations`, asserted zero under load
    in `benchmarks/bench_serve.py`).

The public surface is re-exported as `repro.api.serve`; open a server with
`GraphSession.serve(...)`.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Iterable, Sequence

import numpy as np

from repro.core.plan import QueryPlan, caps_from_plan
from repro.core.query import QueryGraph
from repro.core.result import MatchPage, MatchResult, MatchStats
from repro.core.stream import OpenStream, open_stream
from repro.runtime.resilience import DegradeReason, QueryGuard, degraded_empty

__all__ = [
    "QueryOutcome",
    "QueryServer",
    "ServerConfig",
    "ServerStats",
    "Ticket",
    "bucket_key",
    "summarize_outcomes",
]


def bucket_key(plan: QueryPlan, block_rows: int, kernels: str) -> tuple:
    """A query's shape bucket: the static identity of every executable its
    stream will ask the session cache for. Two queries with equal buckets
    share traces end to end — same STwig specs (match step), same
    capacities and block size (join steps), same kernel backend."""
    caps = caps_from_plan(plan)
    return (
        plan.specs,
        caps["child_cap"],
        caps["join_rows_cap"],
        caps["join_dup_cap"],
        int(block_rows),
        str(kernels),
    )


@dataclasses.dataclass
class ServerConfig:
    """Serving knobs, validated once at server construction.

    ``max_inflight`` bounds how many streams the scheduler interleaves
    (admission control; queued queries' deadlines keep running while they
    wait). ``block_rows`` is the scheduler quantum size — small blocks
    give fair, low-latency interleaving, large blocks amortize per-call
    overhead. ``max_matches`` is the default per-query first-K budget
    (0 = all matches); ``deadline_s`` the default per-query deadline
    (None = none). With ``prefer_warm_buckets`` admission picks queued
    queries whose shape bucket is already in flight before falling back
    to FIFO, maximizing executable sharing under load.
    """

    max_inflight: int = 8
    block_rows: int = 512
    max_matches: int = 1024
    deadline_s: float | None = None
    prefer_warm_buckets: bool = True

    def __post_init__(self):
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if self.block_rows < 1:
            raise ValueError("block_rows must be >= 1")
        if self.max_matches < 0:
            raise ValueError("max_matches must be >= 0 (0 = unbounded)")
        if self.deadline_s is not None and self.deadline_s < 0:
            raise ValueError("deadline_s must be >= 0")


@dataclasses.dataclass
class QueryOutcome:
    """What serving one query produced (the server-side `MatchResult`).

    ``status`` is the satellite-fixed three-way split `launch/serve.py`
    now reports: ``"served"`` (complete, or first-K budget met),
    ``"partial"`` (a capacity overflowed or the query's guard tripped —
    the typed why is in ``result.stats.degrade_reason``), ``"failed"``
    (an exception inside this query's quanta; the server kept running).
    """

    result: MatchResult
    status: str                  # "served" | "partial" | "failed"
    bucket: tuple
    pages: list[MatchPage]
    queue_s: float               # submission -> admission
    wall_s: float                # submission -> completion
    ttfp_s: float | None         # submission -> first non-empty page
    error: str | None = None     # repr of the per-query exception, if any

    @property
    def rows(self) -> np.ndarray:
        return self.result.rows

    @property
    def n_matches(self) -> int:
        return self.result.n_matches

    @property
    def stats(self) -> MatchStats:
        return self.result.stats


class Ticket:
    """The caller's handle on one submitted query — thread-safe; resolved
    by the scheduler. ``result()`` blocks (so it belongs with a started
    server or after ``run_until_idle``); ``done()`` polls."""

    def __init__(self, query: QueryGraph, bucket: tuple):
        self.query = query
        self.bucket = bucket
        self._event = threading.Event()
        self._outcome: QueryOutcome | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> QueryOutcome:
        if not self._event.wait(timeout):
            raise TimeoutError("query still in flight — is the server "
                               "running (started or pumped to idle)?")
        assert self._outcome is not None
        return self._outcome

    def _resolve(self, outcome: QueryOutcome) -> None:
        self._outcome = outcome
        self._event.set()


@dataclasses.dataclass
class ServerStats:
    """Cumulative serving counters (scheduler-thread owned)."""

    submitted: int = 0
    admitted: int = 0
    served: int = 0
    partial: int = 0
    failed: int = 0
    setup_quanta: int = 0        # admissions that ran exploration/fetch
    join_quanta: int = 0         # block joins the scheduler dispatched
    warm_admissions: int = 0     # admitted into an already-live bucket
    peak_inflight: int = 0       # deepest concurrent in-flight set seen
    # errors escaping the scheduler loop itself — per-query failures never
    # count here; the serving SLO is that this stays 0 under overload
    global_degradations: int = 0
    buckets: dict[tuple, int] = dataclasses.field(default_factory=dict)

    @property
    def completed(self) -> int:
        return self.served + self.partial + self.failed

    def as_dict(self) -> dict:
        return {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "served": self.served,
            "partial": self.partial,
            "failed": self.failed,
            "setup_quanta": self.setup_quanta,
            "join_quanta": self.join_quanta,
            "warm_admissions": self.warm_admissions,
            "peak_inflight": self.peak_inflight,
            "global_degradations": self.global_degradations,
            "n_buckets": len(self.buckets),
        }


def summarize_outcomes(outcomes: Iterable[QueryOutcome]) -> dict:
    """The served/partial/failed split plus totals — one dict both
    `launch/serve.py` and the bench print from (and tests pin)."""
    out = {"served": 0, "partial": 0, "failed": 0, "n_matches": 0}
    for o in outcomes:
        out[o.status] += 1
        out["n_matches"] += o.n_matches
    return out


@dataclasses.dataclass(eq=False)
class _InFlight:
    """Scheduler-private state of one admitted query."""

    ticket: Ticket
    plan: QueryPlan
    guard: QueryGuard | None
    budget: int                  # first-K budget (0 = all matches)
    block_rows: int
    t_submit: float
    t_admit: float
    engine_kw: dict
    stream: OpenStream | None = None
    blocks: object = None        # the stream's block iterator
    pages: list[MatchPage] = dataclasses.field(default_factory=list)
    emitted: int = 0
    t_first_page: float | None = None

    def take(self, page: MatchPage) -> bool:
        """Accumulate one block's page (trimmed to the remaining budget);
        True when the first-K budget is met and the stream can close —
        the remaining blocks' joins are then never executed."""
        if self.budget:
            room = self.budget - self.emitted
            if page.rows.shape[0] > room:
                page = dataclasses.replace(page, rows=page.rows[:room])
        if page.rows.shape[0] and self.t_first_page is None:
            self.t_first_page = time.perf_counter()
        self.pages.append(page)
        self.emitted += page.rows.shape[0]
        return bool(self.budget) and self.emitted >= self.budget


class QueryServer:
    """Continuous-batching serving loop over one `GraphSession`.

    Synchronous use (one caller, e.g. a launcher or a test)::

        outcomes = session.serve(max_inflight=8).serve(queries)

    Open-loop use (submissions arrive while the scheduler runs)::

        with session.serve(deadline_s=0.5) as server:   # scheduler thread
            tickets = [server.submit(q) for q in arriving_queries]
            outcomes = [t.result() for t in tickets]

    The scheduler itself is single-threaded — the device executes one
    program at a time anyway; what continuous batching buys is that the
    one thread always has a next quantum from SOME query, and that the
    quanta of expensive queries interleave with (never block) cheap ones.
    `submit` is safe from any thread.
    """

    def __init__(self, session, config: ServerConfig | None = None):
        self.session = session
        self.config = config or ServerConfig()
        self.stats = ServerStats()
        self._lock = threading.Lock()
        self._pending: deque = deque()     # submissions, any thread
        self._queue: deque = deque()       # admission queue, scheduler only
        self._inflight: list[_InFlight] = []
        self._rr = 0                       # round-robin cursor
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- submit
    def submit(
        self,
        query: QueryGraph,
        *,
        max_matches: int | None = None,
        deadline_s: float | None = None,
        block_rows: int | None = None,
        engine_kw: dict | None = None,
        **caps,
    ) -> Ticket:
        """Admit ``query`` to the serving queue and return its `Ticket`.

        Planning happens here (host-side, cheap) so the ticket knows its
        shape bucket before admission; the deadline guard is armed here
        too, so time spent queued counts against the deadline — an
        overloaded server sheds expired queries at admission instead of
        running them late.
        """
        cfg = self.config
        budget = cfg.max_matches if max_matches is None else int(max_matches)
        deadline = cfg.deadline_s if deadline_s is None else deadline_s
        rows = cfg.block_rows if block_rows is None else int(block_rows)
        plan = self.session.compile(query, **caps).plan
        guard = None
        if deadline is not None:
            guard = QueryGuard(deadline_s=deadline)
            guard.start()
        entry = _InFlight(
            ticket=Ticket(query, bucket_key(plan, rows, self.session.kernels.name)),
            plan=plan,
            guard=guard,
            budget=budget,
            block_rows=rows,
            t_submit=time.perf_counter(),
            t_admit=0.0,
            engine_kw=dict(engine_kw or {}),
        )
        with self._lock:
            self._pending.append(entry)
        self._wake.set()
        return entry.ticket

    # ---------------------------------------------------------- scheduler
    def step(self) -> bool:
        """One scheduler quantum: admit if a slot is free, then run either
        one query's stream setup (exploration/fetch) or one block join,
        round-robin across the in-flight set. Returns False when idle
        (nothing queued, nothing in flight)."""
        self._drain_pending()
        self._admit()
        if not self._inflight:
            return False
        i = self._rr % len(self._inflight)
        entry = self._inflight[i]
        try:
            if entry.stream is None:
                entry.stream = open_stream(
                    self.session.engine,
                    entry.ticket.query,
                    entry.plan,
                    block_rows=entry.block_rows,
                    guard=entry.guard,
                    **entry.engine_kw,
                )
                entry.blocks = entry.stream.blocks()
                self.stats.setup_quanta += 1
                # keep the cursor here: the freshly-set-up query gets its
                # first join quantum next, so its first page lands right
                # after admission instead of a full round-robin lap later
                self._rr = i
                return True
            page = next(entry.blocks)
        except StopIteration:
            self._retire(i, entry)
            return True
        except Exception as exc:  # noqa: BLE001 — per-query isolation:
            # one query's fault must not take down its bucket-mates
            self._retire(i, entry, error=exc)
            return True
        self.stats.join_quanta += 1
        if entry.take(page):
            entry.blocks.close()  # budget met: remaining blocks never join
            self._retire(i, entry)
        else:
            self._rr = i + 1
        return True

    def run_until_idle(self) -> None:
        """Pump the scheduler until queue and in-flight set are empty (the
        synchronous serving mode)."""
        try:
            while self.step():
                pass
        except Exception:
            self.stats.global_degradations += 1
            raise

    def serve(
        self, queries: Sequence[QueryGraph] | Iterable[QueryGraph], **kw
    ) -> list[QueryOutcome]:
        """Submit a whole workload and serve it to completion; outcomes
        come back in submission order. ``kw`` is per-query `submit`
        keywords applied to every query. Works in both modes: with the
        background thread running it just waits, otherwise it pumps."""
        tickets = [self.submit(q, **kw) for q in queries]
        if self._thread is None:
            self.run_until_idle()
        return [t.result() for t in tickets]

    # ------------------------------------------------------ thread driver
    def start(self) -> "QueryServer":
        """Run the scheduler on a background thread (open-loop serving:
        `submit` from any thread, `Ticket.result()` to collect)."""
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                try:
                    busy = self.step()
                except Exception:  # noqa: BLE001 — a scheduler-level
                    # failure is the one thing counted as global
                    self.stats.global_degradations += 1
                    continue
                if not busy:
                    self._wake.wait(timeout=0.001)
                    self._wake.clear()

        self._thread = threading.Thread(
            target=loop, name="repro-query-server", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the scheduler thread (in-flight work finishes its current
        quantum; unfinished tickets stay unresolved)."""
        if self._thread is None:
            return
        self._stop.set()
        self._wake.set()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "QueryServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ plumbing
    def _drain_pending(self) -> None:
        with self._lock:
            moved = list(self._pending)
            self._pending.clear()
        for entry in moved:
            self.stats.submitted += 1
            b = entry.ticket.bucket
            self.stats.buckets[b] = self.stats.buckets.get(b, 0) + 1
            self._queue.append(entry)

    def _live_buckets(self) -> set:
        return {e.ticket.bucket for e in self._inflight}

    def _admit(self) -> None:
        while self._queue and len(self._inflight) < self.config.max_inflight:
            idx = 0
            if self.config.prefer_warm_buckets and len(self._queue) > 1:
                live = self._live_buckets()
                idx = next(
                    (k for k, e in enumerate(self._queue)
                     if e.ticket.bucket in live),
                    0,
                )
            entry = self._queue[idx]
            del self._queue[idx]
            entry.t_admit = time.perf_counter()
            # admission control: a query whose deadline expired while
            # queued is shed here — degraded per-query, never run late
            reason = entry.guard.check() if entry.guard is not None else None
            if reason is not None:
                self._finish(entry, degraded=reason)
                continue
            if entry.ticket.bucket in self._live_buckets():
                self.stats.warm_admissions += 1
            self.stats.admitted += 1
            self._inflight.append(entry)
            self.stats.peak_inflight = max(
                self.stats.peak_inflight, len(self._inflight)
            )

    def _retire(self, i: int, entry: _InFlight, error=None) -> None:
        self._inflight.pop(i)
        self._rr = i
        self._finish(entry, error=error)

    def _finish(
        self, entry: _InFlight, error=None, degraded: DegradeReason | None = None
    ) -> None:
        now = time.perf_counter()
        if degraded is not None:
            # shed at admission: never opened, typed empty partial result
            result = degraded_empty(
                entry.plan.n_qnodes, self.session.backend, degraded
            )
        else:
            rows = (
                np.concatenate([p.rows for p in entry.pages], axis=0)
                if entry.pages
                else np.zeros((0, entry.plan.n_qnodes), np.int64)
            )
            stats = (
                entry.stream.stats
                if entry.stream is not None
                else MatchStats(backend=self.session.backend)
            )
            complete = (
                all(p.complete for p in entry.pages)
                and stats.degrade_reason is None
                and error is None
            )
            result = MatchResult(
                rows=rows,
                n_matches=int(rows.shape[0]),
                complete=complete,
                stats=stats,
            )
        if error is not None:
            status = "failed"
        elif result.complete:
            status = "served"
        else:
            status = "partial"
        setattr(self.stats, status, getattr(self.stats, status) + 1)
        entry.ticket._resolve(QueryOutcome(
            result=result,
            status=status,
            bucket=entry.ticket.bucket,
            pages=list(entry.pages),
            queue_s=max(0.0, entry.t_admit - entry.t_submit),
            wall_s=now - entry.t_submit,
            ttfp_s=(
                None
                if entry.t_first_page is None
                else entry.t_first_page - entry.t_submit
            ),
            error=None if error is None else repr(error),
        ))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"QueryServer(inflight={len(self._inflight)}, "
            f"queued={len(self._queue)}, stats={self.stats.as_dict()})"
        )
