"""Deterministic, seeded fault injection for query execution.

A `ChaosInjector` simulates the failure modes of the paper's distributed
memory cloud — a slow shard, a dead shard, a truncated fetch payload,
forced capacity overflow — so the resilience layer
(`repro.runtime.resilience`) can be tested end to end: under every
injected fault the engines must return a typed partial result (a correct
*subset* of the true row set, ``complete=False``, the right
`DegradeReason`), never hang, crash, or return wrong rows.

Faults act at host orchestration boundaries, never inside jitted
programs: host callbacks are banned from hot traces (staticcheck pass a),
and an SPMD program that raises on one shard would deadlock the others —
exactly the failure class this layer exists to model, not to cause. So:

  * *slow shard* — a host-side delay charged before the fetch and before
    each block join (the shard gates the step; TPU SPMD reality).
  * *dead shard* — each fetch attempt raises `ShardFaultError` until the
    configured heal point; the sharded engine retries with the
    `RetryPolicy`'s jittered backoff, then degrades to the surviving
    shards' rows by masking the dead shard's stacked validity host-side.
  * *truncated fetch* — the tail of the configured shard's non-head
    table rows is dropped pre-gather (the head table is never fetched —
    Theorem 5 — so it is never truncated in transit).
  * *forced overflow* — ORed into the engines' host-side overflow flags,
    driving the adaptive-retry / ceiling machinery without needing a
    pathological graph.

`ChaosKernels` wraps a `Kernels` backend with per-op trace-time
accounting under a distinct ``name`` — the name keys every cached
executable, so chaos runs can never poison a clean session's cache.

Everything is seeded (`ChaosConfig.seed`): two injectors with equal
configs observe identical delays, deaths and heal points.
"""
from __future__ import annotations

import collections
import dataclasses
import random

from repro.core.backend import Kernels

__all__ = ["ChaosConfig", "ChaosInjector", "ChaosKernels", "ShardFaultError"]


class ShardFaultError(RuntimeError):
    """A fetch from ``shard`` failed (the injected dead-shard fault)."""

    def __init__(self, shard: int):
        super().__init__(f"fetch from shard {shard} failed")
        self.shard = int(shard)


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Which faults to inject. All deterministic given ``seed``."""

    seed: int = 0
    # slow shard: delay charged at the fetch and before every block join
    slow_shard: int | None = None
    slow_delay_s: float = 0.02
    # dead shard: fetch attempts raise until `dead_heals_after` attempts
    # have failed (None = never heals; engines degrade after their retry
    # budget)
    dead_shard: int | None = None
    dead_heals_after: int | None = None
    # truncated fetch payload: only `truncate_keep_frac` of the shard's
    # non-head table rows survive the (simulated) transfer
    truncate_shard: int | None = None
    truncate_keep_frac: float = 0.5
    # force the capacity-overflow path regardless of the data
    force_overflow: bool = False


class ChaosInjector:
    """Host-side fault source the engines consult at their orchestration
    boundaries. Construct from a `ChaosConfig` (or its fields as kwargs)
    and pass to ``GraphSession.open(..., chaos=...)``."""

    def __init__(self, config: ChaosConfig | None = None, **kw):
        self.config = config if config is not None else ChaosConfig(**kw)
        self._rng = random.Random(self.config.seed)
        self.fetch_attempts = 0
        # trace-time op invocations recorded by `ChaosKernels`
        self.op_calls: collections.Counter = collections.Counter()
        # chronological fault log: (event, shard) pairs, for assertions
        self.events: list[tuple[str, int]] = []

    # ------------------------------------------------------------- kernels
    def wrap_kernels(self, kernels: Kernels) -> "ChaosKernels":
        if isinstance(kernels, ChaosKernels):
            return kernels
        return ChaosKernels(kernels, self)

    # -------------------------------------------------------------- faults
    def forced_overflow(self) -> bool:
        return self.config.force_overflow

    def fetch_delay(self) -> tuple[int, float] | None:
        """(shard, seconds) to stall the fetch for, or None. Jittered but
        seeded: deterministic per injector."""
        c = self.config
        if c.slow_shard is None:
            return None
        d = c.slow_delay_s * (0.75 + 0.5 * self._rng.random())
        self.events.append(("slow", c.slow_shard))
        return c.slow_shard, d

    def block_delay(self) -> float:
        """Per-block-join stall contributed by the slow shard (every block
        waits on the slowest shard's join step)."""
        c = self.config
        if c.slow_shard is None:
            return 0.0
        return c.slow_delay_s * (0.75 + 0.5 * self._rng.random())

    def try_fetch(self) -> None:
        """One fetch attempt. Raises `ShardFaultError` while the configured
        dead shard is down; returns quietly once it healed (or when no
        death is configured)."""
        c = self.config
        if c.dead_shard is None:
            return
        self.fetch_attempts += 1
        if c.dead_heals_after is None or self.fetch_attempts <= c.dead_heals_after:
            self.events.append(("dead", c.dead_shard))
            raise ShardFaultError(c.dead_shard)
        self.events.append(("healed", c.dead_shard))

    def truncation(self) -> tuple[int, float] | None:
        """(shard, keep_frac) for the truncated-payload fault, or None."""
        c = self.config
        if c.truncate_shard is None:
            return None
        self.events.append(("truncated", c.truncate_shard))
        return c.truncate_shard, c.truncate_keep_frac


class ChaosKernels(Kernels):
    """Delegating `Kernels` wrapper with per-op trace-time accounting.

    The distinct ``name`` participates in every executable-cache key, so
    chaos-wrapped executables live beside — never instead of — the clean
    backend's (same invariant `GraphSession.set_kernels` relies on).
    """

    def __init__(self, inner: Kernels, injector: ChaosInjector):
        self.inner = inner
        self.injector = injector
        self.name = f"chaos({inner.name})"

    def _op(self, op: str, *args, **kw):
        self.injector.op_calls[op] += 1
        return getattr(self.inner, op)(*args, **kw)

    def bitset_pack(self, *args, **kw):
        return self._op("bitset_pack", *args, **kw)

    def bitset_unpack(self, *args, **kw):
        return self._op("bitset_unpack", *args, **kw)

    def bitset_lookup(self, *args, **kw):
        return self._op("bitset_lookup", *args, **kw)

    def bitset_build(self, *args, **kw):
        return self._op("bitset_build", *args, **kw)

    def candidate_filter(self, *args, **kw):
        return self._op("candidate_filter", *args, **kw)

    def stwig_expand(self, *args, **kw):
        return self._op("stwig_expand", *args, **kw)

    def hash_join_probe(self, *args, **kw):
        return self._op("hash_join_probe", *args, **kw)

    def cin_layer(self, *args, **kw):
        return self._op("cin_layer", *args, **kw)
