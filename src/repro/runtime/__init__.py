from repro.runtime.chaos import (
    ChaosConfig,
    ChaosInjector,
    ChaosKernels,
    ShardFaultError,
)
from repro.runtime.fault_tolerance import (
    SimulatedPreemption,
    TrainSupervisor,
    elastic_restore,
    straggler_update,
)
from repro.runtime.resilience import (
    DegradeReason,
    QueryGuard,
    RetryPolicy,
    adaptive_run,
)
from repro.runtime.server import (
    QueryOutcome,
    QueryServer,
    ServerConfig,
    ServerStats,
    Ticket,
    summarize_outcomes,
)

__all__ = [
    "ChaosConfig",
    "ChaosInjector",
    "ChaosKernels",
    "DegradeReason",
    "QueryGuard",
    "QueryOutcome",
    "QueryServer",
    "RetryPolicy",
    "ServerConfig",
    "ServerStats",
    "ShardFaultError",
    "SimulatedPreemption",
    "Ticket",
    "TrainSupervisor",
    "adaptive_run",
    "elastic_restore",
    "straggler_update",
    "summarize_outcomes",
]
