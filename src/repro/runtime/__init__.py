from repro.runtime.fault_tolerance import (
    SimulatedPreemption,
    TrainSupervisor,
    elastic_restore,
)

__all__ = ["SimulatedPreemption", "TrainSupervisor", "elastic_restore"]
