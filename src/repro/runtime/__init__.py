from repro.runtime.chaos import (
    ChaosConfig,
    ChaosInjector,
    ChaosKernels,
    ShardFaultError,
)
from repro.runtime.fault_tolerance import (
    SimulatedPreemption,
    TrainSupervisor,
    elastic_restore,
    straggler_update,
)
from repro.runtime.resilience import (
    DegradeReason,
    QueryGuard,
    RetryPolicy,
    adaptive_run,
)

__all__ = [
    "ChaosConfig",
    "ChaosInjector",
    "ChaosKernels",
    "DegradeReason",
    "QueryGuard",
    "RetryPolicy",
    "ShardFaultError",
    "SimulatedPreemption",
    "TrainSupervisor",
    "adaptive_run",
    "elastic_restore",
    "straggler_update",
]
