"""Fault tolerance / elasticity for long-running training (deliverable: the
large-scale-runnability axis).

Mechanisms (each exercised by tests/test_fault_tolerance.py):

  * **checkpoint/restart** — ``TrainSupervisor`` wraps the step loop with
    periodic async checkpoints and restart-from-latest; a failure injector
    simulates preemptions and the loop resumes losslessly (bitwise-equal
    state to an uninterrupted run, since steps are deterministic).
  * **elastic rescale** — a checkpoint written on an N-way mesh restores
    onto an M-way mesh (`elastic_restore`): leaves are host-gathered numpy,
    so resharding is a device_put with the new mesh's NamedShardings.
    Survivors of a dead pod rebuild a (1, 16, 16) mesh and continue.
  * **straggler mitigation** — at the step level every collective is
    synchronous, so one slow chip gates the step (TPU SPMD reality). The
    mitigations here are structural: (i) bounded per-round work in the
    matching engine (a straggler bounds one round, never the query), and
    (ii) the supervisor tracks a rolling step-time EWMA and flags
    step-time regressions > ``straggler_factor`` so the launcher can
    evict/replace the slow host between checkpoints (the standard
    TPU-fleet playbook); (iii) data loading is host-local and prefetched,
    never a global barrier.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.checkpoint import Checkpointer


class SimulatedPreemption(RuntimeError):
    pass


def straggler_update(
    ewma: float | None, dt: float, factor: float
) -> tuple[float, bool]:
    """One step of straggler detection: compare ``dt`` against the EWMA of
    the steps *before* it, then fold it in.

    The comparison must use the previous EWMA: updating first lets the
    straggling step drag the average toward itself and dampen its own
    detection (with the default 0.1 update weight, a step must exceed
    ``factor / (1 - 0.1 * factor)`` × the true baseline instead of
    ``factor`` × — at factor 3, 4.3× instead of 3×). Returns
    ``(new_ewma, straggling)``; the first step seeds the EWMA and is
    never flagged (no baseline to compare against).
    """
    straggling = ewma is not None and dt > factor * ewma
    new_ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
    return new_ewma, straggling


@dataclasses.dataclass
class TrainSupervisor:
    checkpointer: Checkpointer
    ckpt_every: int = 50
    straggler_factor: float = 3.0
    # failure injection for tests: step -> exception factory
    fail_at: dict[int, Callable[[], Exception]] = dataclasses.field(
        default_factory=dict
    )

    def run(
        self,
        *,
        state: Any,                  # (params, opt_state) pytree
        step_fn: Callable,           # (state, batch, step) -> (state, metrics)
        batch_fn: Callable,          # step -> batch (deterministic!)
        n_steps: int,
        start_step: int | None = None,
        shardings: Any = None,
    ):
        """Run to ``n_steps``, resuming from the latest checkpoint if any.
        Returns (state, history). Raises SimulatedPreemption out of the loop
        when injected — callers re-invoke ``run`` to model a restart."""
        latest = self.checkpointer.latest_step()
        step = 0
        if start_step is not None:
            step = start_step
        elif latest is not None:
            state = self.checkpointer.restore(latest, state, shardings)
            step = latest
        history: list[dict] = []
        ewma = None
        while step < n_steps:
            if step in self.fail_at:
                exc = self.fail_at.pop(step)()
                raise exc
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_fn(step), step)
            jax.block_until_ready(jax.tree.leaves(state)[0])
            dt = time.perf_counter() - t0
            ewma, straggling = straggler_update(ewma, dt, self.straggler_factor)
            history.append(
                {"step": step, "dt": dt, "straggler_flag": straggling, **{
                    k: float(v) for k, v in metrics.items()
                }}
            )
            step += 1
            if step % self.ckpt_every == 0 or step == n_steps:
                self.checkpointer.save(step, state)
        self.checkpointer.wait()
        return state, history


def elastic_restore(checkpointer: Checkpointer, like, new_shardings):
    """Restore the latest checkpoint onto a *different* mesh (elastic
    rescale after losing or gaining hosts)."""
    latest = checkpointer.latest_step()
    assert latest is not None, "no checkpoint to restore"
    return checkpointer.restore(latest, like, new_shardings), latest
