"""`CompiledQuery`: the run side of the compile/run split.

Compilation (planning + executable cache keys) happens once in
`GraphSession.compile`; a `CompiledQuery` can then be run repeatedly —
one-shot (`run`), or streamed in pages with the paper's pipelined first-K
semantics (`stream`). Adaptive capacity growth recompiles escalated plans
through the same session cache, so retries reuse every executable whose
static spec survived the escalation.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Iterator

import numpy as np

from repro.core.plan import QueryPlan, caps_from_plan
from repro.core.query import QueryGraph
from repro.core.result import MatchPage, MatchResult
from repro.core.stream import stream_blocks  # noqa: F401  (re-export: the
# shared per-block streaming driver both engines and `stream` run on)
from repro.runtime.resilience import QueryGuard, RetryPolicy, adaptive_run

if TYPE_CHECKING:  # pragma: no cover
    from repro.api.session import GraphSession


@dataclasses.dataclass
class CompiledQuery:
    """A planned query bound to its session. Reusable and cheap to rerun."""

    session: "GraphSession"
    query: QueryGraph
    plan: QueryPlan
    caps: dict

    @property
    def kernels(self) -> str:
        """Name of the kernel backend this query's executables are built
        against (it keys the session's executable cache, so flipping the
        session's kernels re-resolves here automatically)."""
        return self.session.engine.kernels.name

    def run(
        self,
        *,
        max_matches: int | None = None,
        adaptive: bool = True,
        max_retries: int = 6,
        deadline_s: float | None = None,
        memory_budget_bytes: float | None = None,
        guard: QueryGuard | None = None,
        retry_policy: RetryPolicy | None = None,
        **engine_kw,
    ) -> MatchResult:
        """Execute the compiled plan.

        ``max_matches`` overrides the compiled plan's value (0 = all
        matches) without replanning. With ``adaptive=True``, a capacity
        overflow re-plans with doubled block sizes (paper §4.2) and reruns,
        up to ``max_retries`` times; ``adaptive=False`` returns the first,
        possibly partial, result — the paper's first-K semantics.
        ``engine_kw`` passes backend-specific options through (e.g.
        ``use_ring=True`` on the sharded backend).

        Resilience (`repro.runtime.resilience`): ``deadline_s`` /
        ``memory_budget_bytes`` build a `QueryGuard` (or pass ``guard``
        to share one across calls) enforced between retries — a trip
        returns the partial result with a typed
        ``stats.degrade_reason``; ``retry_policy`` controls backoff and
        the cap-growth byte ceiling. Escalated plans recompile through
        the session cache, so retries reuse every executable whose
        static spec survived the escalation.
        """
        plan = self.plan
        if max_matches is not None and max_matches != plan.max_matches:
            plan = dataclasses.replace(plan, max_matches=max_matches)
        engine = self.session.engine
        policy = retry_policy or RetryPolicy(max_retries=max_retries)
        if guard is None and (
            deadline_s is not None or memory_budget_bytes is not None
        ):
            guard = QueryGuard(
                deadline_s=deadline_s,
                memory_budget_bytes=memory_budget_bytes,
            )

        def first() -> MatchResult:
            return engine._match_once(
                self.query, plan=plan, retry_policy=policy, **engine_kw
            )

        def escalate(caps: dict) -> MatchResult:
            esc = self.session.replan(
                self.query, **dict(caps, max_matches=plan.max_matches)
            )
            return engine._match_once(
                self.query, plan=esc, retry_policy=policy, **engine_kw
            )

        return adaptive_run(
            first,
            escalate,
            caps_from_plan(plan, dict(self.caps)),
            n_qnodes=self.query.n_nodes,
            backend=self.session.backend,
            policy=policy,
            guard=guard,
            adaptive=adaptive,
        )

    def stream(
        self,
        page_size: int = 256,
        *,
        max_matches: int | None = None,
        block_rows: int | None = None,
        deadline_s: float | None = None,
        guard: QueryGuard | None = None,
        **engine_kw,
    ) -> Iterator[MatchPage]:
        """Yield matches in pages of ``page_size`` rows as they materialize
        (pipelined first-K delivery, §6.1). On BOTH backends the join chain
        really runs block-by-block — the sharded engine fetches remote STwig
        tables once, then joins only head rows ``[lo, lo+block_rows)`` per
        shard_map call — so stopping early (e.g. after ``max_matches`` rows,
        enforced here when set) skips the remaining blocks' join work
        entirely. Pages are disjoint and their concatenation equals a
        one-shot ``run(max_matches=0)`` row set.

        ``block_rows`` trades first-page latency for total throughput: each
        block's join re-probes the full fetched tables, so tiny blocks make
        the first page cheap but a fully-consumed stream expensive — prefer
        `run` when you know you want every match.

        ``deadline_s`` (or a shared ``guard``) bounds the stream: the
        guard is checked between blocks, and on expiry the stream ends
        with one final degraded page — pages already delivered stay
        valid, remaining blocks are never joined. Every page carries the
        stream's shared `MatchStats` (retries, final caps, stage times).
        """
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        if guard is None and deadline_s is not None:
            guard = QueryGuard(deadline_s=deadline_s)
        limit = self.plan.max_matches if max_matches is None else max_matches
        blocks = stream_blocks(
            self.session.engine,
            self.query,
            self.plan,
            block_rows=block_rows or max(page_size, 1024),
            guard=guard,
            **engine_kw,
        )
        buf: list[np.ndarray] = []
        buffered = 0
        emitted = 0
        index = 0
        complete = True
        incomplete_seen = False  # some emitted page already carries False
        stats = None  # the stream's shared stats, captured from the blocks

        def page(rows: np.ndarray, complete: bool) -> MatchPage:
            nonlocal index, emitted, incomplete_seen
            incomplete_seen |= not complete
            p = MatchPage(rows=rows, index=index, complete=complete, stats=stats)
            index += 1
            emitted += rows.shape[0]
            return p

        for blk in blocks:
            stats = blk.stats if blk.stats is not None else stats
            complete &= blk.complete
            buf.append(blk.rows)
            buffered += blk.rows.shape[0]
            while buffered >= page_size or (limit and emitted + buffered >= limit):
                # never exceed the limit, even mid-full-page
                take = page_size if not limit else min(page_size, limit - emitted)
                flat = np.concatenate(buf, axis=0) if len(buf) > 1 else buf[0]
                head, tail = flat[:take], flat[take:]
                buf, buffered = ([tail], tail.shape[0]) if tail.shape[0] else ([], 0)
                yield page(head, complete)
                if limit and emitted >= limit:
                    return  # early exit: remaining blocks are never joined
        if buffered:
            flat = np.concatenate(buf, axis=0) if len(buf) > 1 else buf[0]
            if limit:
                flat = flat[: max(0, limit - emitted)]
            if flat.shape[0]:
                yield page(flat, complete)
        if not complete and not incomplete_seen:
            # a capacity overflowed but every emitted page predated the
            # signal (or none had rows): surface it rather than swallow it
            yield page(np.zeros((0, self.plan.n_qnodes), np.int64), False)
