"""`repro.api.serve` — the public serving surface (DESIGN.md §7).

Serving is a first-class facade concern now, not a launcher loop: a
`QueryServer` opened over a `GraphSession` continuously batches block-join
quanta from many in-flight queries on one device, shares traced
executables across shape-bucketed queries via the session's
`ExecutableCache`, and degrades per query (deadline / first-K budget /
fault) — never globally::

    from repro.api import GraphSession
    from repro.api.serve import ServerConfig

    session = GraphSession.open(graph)
    outcomes = session.serve(max_inflight=8, deadline_s=0.5).serve(queries)

    with session.serve() as server:          # open-loop: scheduler thread
        ticket = server.submit(query, max_matches=256)
        outcome = ticket.result()            # QueryOutcome: status + result

Everything here is a re-export of `repro.runtime.server`, which holds the
implementation; this module IS the supported import path (alongside the
top-level `repro.api` names).
"""
from repro.runtime.server import (
    QueryOutcome,
    QueryServer,
    ServerConfig,
    ServerStats,
    Ticket,
    bucket_key,
    summarize_outcomes,
)

__all__ = [
    "QueryOutcome",
    "QueryServer",
    "ServerConfig",
    "ServerStats",
    "Ticket",
    "bucket_key",
    "summarize_outcomes",
]
