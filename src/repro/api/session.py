"""`GraphSession`: one facade over the local and distributed engines.

The session is the unit of engine state: it owns the partitioned graph, the
backend engine, and the keyed `ExecutableCache` shared by every query
compiled in it — so a workload of similar queries pays each jit trace once,
and the cache dies with the session instead of living in module globals.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

from repro.api.compiled import CompiledQuery
from repro.core.backend import Kernels, resolve_kernels
from repro.core.cache import ExecutableCache
from repro.core.deprecation import facade_construction
from repro.core.engine import SubgraphMatcher
from repro.core.plan import QueryPlan
from repro.core.query import QueryGraph
from repro.core.result import MatchResult
from repro.graphstore.csr import Graph
from repro.graphstore.partition import PartitionedGraph

BACKENDS = ("auto", "local", "sharded")


class GraphSession:
    """A query session over one graph. Use `GraphSession.open`, not the
    constructor. Usable as a context manager; `close()` drops the executable
    cache."""

    def __init__(self, pg: PartitionedGraph, engine, backend: str, cache: ExecutableCache):
        self.pg = pg
        self.backend = backend
        self.cache = cache
        self._engine = engine

    # ------------------------------------------------------------- factory
    @classmethod
    def open(
        cls,
        graph_or_pg: Graph | PartitionedGraph,
        *,
        backend: str = "auto",
        kernels: "str | Kernels" = "auto",
        n_shards: int | None = None,
        mesh=None,
        partition_mode: str = "hash",
        cache_size: int = 512,
        chaos=None,
    ) -> "GraphSession":
        """Open a session, selecting and wrapping the right engine.

        ``backend="auto"`` picks "sharded" when a mesh is given or the
        partition has multiple shards (and enough devices exist), else
        "local". A raw `Graph` is partitioned here: into 1 shard for the
        local backend, ``n_shards`` (default: all devices) for sharded.

        ``kernels`` selects the kernel backend every dense inner step draws
        from — ``"auto"`` (Pallas on TPU, jnp elsewhere), ``"jnp"``,
        ``"pallas"``, ``"pallas-interpret"``, or a registered `Kernels`
        instance (`repro.core.backend`). The choice keys every cached
        executable, so sessions can be compared across kernel backends
        without recompiling each other's programs away.

        ``chaos`` attaches a seeded fault injector
        (`repro.runtime.chaos.ChaosInjector`) to the engine: injected
        faults (slow/dead shard, truncated fetch, forced overflow) are
        handled by the resilience layer and surface as typed partial
        results. The injector wraps the kernel backend under a distinct
        name, so chaos executables never collide with clean ones.
        """
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        kern = resolve_kernels(kernels)
        import jax

        n_dev = len(jax.devices())
        if backend == "auto":
            if mesh is not None:
                backend = "sharded"
            elif isinstance(graph_or_pg, PartitionedGraph):
                pg_shards = graph_or_pg.n_shards
                if pg_shards > n_dev:
                    raise ValueError(
                        f"partition has {pg_shards} shards but only {n_dev} "
                        f"device(s) are available — re-partition to ≤{n_dev} "
                        "shards (1 for the local backend) or add devices"
                    )
                backend = "sharded" if pg_shards > 1 else "local"
            elif n_shards is not None and n_shards > 1:
                backend = "sharded"
            else:
                backend = "local"

        if isinstance(graph_or_pg, PartitionedGraph):
            pg = graph_or_pg
        else:
            if backend == "local":
                shards = 1
            else:
                shards = n_shards or (mesh.devices.size if mesh is not None else n_dev)
            pg = PartitionedGraph.build(graph_or_pg, shards, mode=partition_mode)

        cache = ExecutableCache(maxsize=cache_size)
        if backend == "local":
            if pg.n_shards != 1:
                raise ValueError(
                    f"local backend needs a 1-shard partition, got {pg.n_shards} "
                    "shards (use backend='sharded' or re-partition)"
                )
            with facade_construction():
                engine = SubgraphMatcher(
                    pg, cache=cache, kernels=kern, chaos=chaos
                )
        else:
            from jax.sharding import Mesh

            from repro.core.dist import DistributedMatcher

            if mesh is None:
                if pg.n_shards > n_dev:
                    raise ValueError(
                        f"sharded backend needs ≥{pg.n_shards} devices, have {n_dev}"
                    )
                mesh = Mesh(np.array(jax.devices()[: pg.n_shards]), ("data",))
            with facade_construction():
                engine = DistributedMatcher(
                    pg, mesh, cache=cache, kernels=kern, chaos=chaos
                )
        return cls(pg, engine, backend, cache)

    # ----------------------------------------------------------- query API
    def compile(self, query: QueryGraph, **caps) -> CompiledQuery:
        """Plan ``query`` (Algorithm 2 + head selection + static capacities)
        without running it. ``caps`` are `make_plan` keywords (``child_cap``,
        ``join_rows_cap``, ``max_matches``, ...). Executables are built
        lazily on first run and cached in the session by their static spec,
        so recompiling an identical query is free."""
        plan = self._engine.plan(query, **caps)
        return CompiledQuery(session=self, query=query, plan=plan, caps=caps)

    def run(
        self,
        query: QueryGraph,
        *,
        adaptive: bool = True,
        deadline_s: float | None = None,
        memory_budget_bytes: float | None = None,
        retry_policy=None,
        **caps,
    ) -> MatchResult:
        """One-shot convenience: ``compile(query).run()``. ``deadline_s`` /
        ``memory_budget_bytes`` bound the query (a trip returns a partial
        result with a typed ``stats.degrade_reason``); ``retry_policy``
        tunes adaptive escalation (`repro.runtime.resilience`)."""
        return self.compile(query, **caps).run(
            adaptive=adaptive,
            deadline_s=deadline_s,
            memory_budget_bytes=memory_budget_bytes,
            retry_policy=retry_policy,
        )

    def stream(
        self,
        query: QueryGraph,
        *,
        page_size: int = 256,
        max_matches: int | None = None,
        block_rows: int | None = None,
        deadline_s: float | None = None,
        engine_kw: dict | None = None,
        **caps,
    ):
        """One-shot convenience: ``compile(query).stream(...)`` — pipelined
        first-K pages on either backend. ``block_rows`` is forwarded to
        `CompiledQuery.stream` (the latency/throughput knob),
        ``deadline_s`` bounds the stream at block boundaries, ``engine_kw``
        carries backend options (e.g. ``{"use_ring": True}``), and ``caps``
        go to `compile`."""
        return self.compile(query, **caps).stream(
            page_size=page_size,
            max_matches=max_matches,
            block_rows=block_rows,
            deadline_s=deadline_s,
            **(engine_kw or {}),
        )

    def serve(self, **cfg) -> "QueryServer":
        """Open a continuous-batching `QueryServer` over this session
        (DESIGN.md §7). ``cfg`` keywords are `ServerConfig` fields::

            server = session.serve(max_inflight=8, deadline_s=0.5)
            outcomes = server.serve(queries)       # synchronous batch
            with session.serve() as srv:           # background scheduler
                t = srv.submit(q)
                out = t.result()

        Concurrent queries with identical plan shapes share one traced
        executable via this session's `ExecutableCache`; the server
        interleaves their block joins on the one device and enforces
        per-query deadlines/budgets so overload degrades per query, never
        globally."""
        from repro.runtime.server import QueryServer, ServerConfig

        return QueryServer(self, ServerConfig(**cfg))

    def run_batch(
        self,
        queries: Sequence[QueryGraph] | Iterable[QueryGraph],
        *,
        adaptive: bool = True,
        **caps,
    ) -> list[MatchResult]:
        """Run a workload, amortizing compilation: all queries are planned
        up front and executed against the shared executable cache, so
        queries with identical STwig specs / join schemas reuse each other's
        jitted programs. Results are returned in input order and are
        identical to sequential `run` calls."""
        compiled = [self.compile(q, **caps) for q in queries]
        return [cq.run(adaptive=adaptive) for cq in compiled]

    # ------------------------------------------------------------ plumbing
    @property
    def engine(self):
        """The wrapped backend engine (for low-level access; prefer the
        facade methods)."""
        return self._engine

    @property
    def kernels(self) -> Kernels:
        """The kernel backend the engine's dense steps draw from."""
        return self._engine.kernels

    def set_kernels(self, kernels: "str | Kernels") -> "GraphSession":
        """Switch the kernel backend for subsequent runs. Safe mid-session:
        executables are keyed by (static spec, kernels name), so previously
        compiled programs survive and a later switch back reuses them."""
        self._engine.kernels = resolve_kernels(kernels)
        return self

    def replan(self, query: QueryGraph, **caps) -> QueryPlan:
        return self._engine.plan(query, **caps)

    def close(self) -> None:
        self.cache.clear()

    def __enter__(self) -> "GraphSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"GraphSession(backend={self.backend!r}, "
            f"kernels={self.kernels.name!r}, n_shards={self.pg.n_shards}, "
            f"cache={len(self.cache)} executables)"
        )
