"""`repro.api` — the unified query facade (the paper's query proxy, §6.1).

One entry point over both engines, with an explicit compile/run split::

    from repro.api import GraphSession

    sess = GraphSession.open(graph)                 # backend="auto"
    cq = sess.compile(query, max_matches=1024)      # plan + cache key
    res = cq.run(adaptive=False)                    # MatchResult
    for page in cq.stream(page_size=256):           # pipelined first-K
        ...
    results = sess.run_batch(queries)               # amortized compiles
    outcomes = sess.serve(max_inflight=8).serve(qs) # continuous batching

`GraphSession` selects and wraps the right engine (`SubgraphMatcher` or
`DistributedMatcher`), owns the keyed `ExecutableCache` that used to hide in
module-level ``lru_cache`` state, and returns typed `MatchResult` /
`MatchStats` objects instead of raw dicts. Serving many users from one
device program is `repro.api.serve` (`QueryServer` et al., re-exported
here); `__all__` below IS the public surface — anything else is internal.
"""
from repro.api.compiled import CompiledQuery
from repro.api.serve import (
    QueryOutcome,
    QueryServer,
    ServerConfig,
    ServerStats,
    Ticket,
    summarize_outcomes,
)
from repro.api.session import GraphSession
from repro.core.cache import ExecutableCache
from repro.core.result import MatchPage, MatchResult, MatchStats

__all__ = [
    "GraphSession",
    "CompiledQuery",
    "ExecutableCache",
    "MatchResult",
    "MatchStats",
    "MatchPage",
    "QueryServer",
    "ServerConfig",
    "ServerStats",
    "QueryOutcome",
    "Ticket",
    "summarize_outcomes",
]
