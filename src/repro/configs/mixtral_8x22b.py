"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf]."""
from repro.configs.base import ArchEntry, LMConfig, MoEConfig, register

CONFIG = LMConfig(
    name="mixtral-8x22b",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384, capacity_factor=1.25),
    remat="block",
)


def smoke() -> LMConfig:
    return LMConfig(
        name="mixtral-8x22b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=8,
        dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, capacity_factor=2.0),
    )


ENTRY = register(
    ArchEntry(
        arch_id="mixtral-8x22b",
        family="lm",
        config=CONFIG,
        smoke=smoke,
        # long_500k runs: SWA bounds the attention window (sub-quadratic)
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    )
)
