"""gin-tu [gnn]: 5L d_hidden=64 sum aggregator, learnable eps
[arXiv:1810.00826]."""
from repro.configs.base import ArchEntry, GNNConfig, register

CONFIG = GNNConfig(
    name="gin-tu", kind="gin", n_layers=5, d_hidden=64,
    aggregator="sum", learnable_eps=True, n_classes=16,
)


def smoke() -> GNNConfig:
    return GNNConfig(
        name="gin-tu-smoke", kind="gin", n_layers=2, d_hidden=16, d_in=8,
        n_classes=5,
    )


ENTRY = register(
    ArchEntry(
        arch_id="gin-tu", family="gnn", config=CONFIG, smoke=smoke,
        shapes=("full_graph_sm", "minibatch_lg", "ogb_products", "molecule"),
    )
)
