"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff_expert=2048
vocab=129280 — MLA (q_lora 1536, kv_lora 512, rope 64), 1 shared + 256
routed top-8 sigmoid router w/ aux-free bias + group-limited routing
(8 groups, top-4), first 3 layers dense (d_ff 18432), MTP
[arXiv:2412.19437; hf]."""
from repro.configs.base import ArchEntry, LMConfig, MLAConfig, MoEConfig, register

CONFIG = LMConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=18432,
    vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, d_nope=128, d_rope=64, d_v=128),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        d_ff_expert=2048,
        n_shared=1,
        router="sigmoid",
        router_bias_balancing=True,
        n_groups=8,
        top_groups=4,
        first_k_dense=3,
        d_ff_dense=18432,
        capacity_factor=1.25,
    ),
    mtp_depth=1,
    remat="block",
)


def smoke() -> LMConfig:
    return LMConfig(
        name="deepseek-v3-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        dtype="float32",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, d_nope=16, d_rope=8, d_v=16),
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            d_ff_expert=32,
            n_shared=1,
            router="sigmoid",
            router_bias_balancing=True,
            n_groups=2,
            top_groups=1,
            first_k_dense=1,
            d_ff_dense=128,
            capacity_factor=2.0,
        ),
        mtp_depth=1,
    )


ENTRY = register(
    ArchEntry(
        arch_id="deepseek-v3-671b",
        family="lm",
        config=CONFIG,
        smoke=smoke,
        # long_500k runs: MLA latent KV cache (576/token) makes 500k-context
        # decode practical; per-step attention is O(L·d_c) (see DESIGN.md §4)
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    )
)
