"""Config dataclasses + the architecture registry.

Every assigned architecture registers a ``Config`` here via its module in
``repro/configs/<id>.py``; launchers select with ``--arch <id>``. Each config
also provides a ``smoke()`` reduction — same family, tiny dims — used by the
per-arch CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable


# --------------------------------------------------------------------- LM
@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    router: str = "softmax"          # softmax (Mixtral) | sigmoid (DeepSeek-V3)
    router_bias_balancing: bool = False  # aux-loss-free bias update (DSv3)
    n_groups: int = 1                # group-limited routing (DSv3)
    top_groups: int = 1
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.0
    first_k_dense: int = 0           # leading dense layers (DSv3: 3)
    d_ff_dense: int = 0              # d_ff of those dense layers
    # §Perf: dispatch tokens in DP-local groups so sort/gather never cross
    # shards (1 = paper-faithful single global dispatch)
    dispatch_groups: int = 1


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    act: str = "swiglu"              # swiglu | geglu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    sliding_window: int | None = None
    attn_logit_softcap: float | None = None
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mtp_depth: int = 0               # multi-token-prediction heads (DSv3)
    emb_scale: bool = False          # gemma scales embeddings by sqrt(d)
    dtype: str = "bfloat16"
    remat: str = "none"              # none | block | full
    # §Perf: flash-decoding style split-KV decode — per-block softmax stats
    # combined across blocks, so a kv_seq-sharded cache never all-gathers
    decode_kv_blocks: int = 1
    # §Perf: inference weight placement — "fsdp" (train-style, gathers every
    # step) or "tp_replicated" (TP-sharded, replicated over DP: no per-step
    # weight collectives; experts shard over data×model when divisible)
    inference_param_sharding: str = "fsdp"

    @property
    def attn_kind(self) -> str:
        return "mla" if self.mla is not None else "gqa"

    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.mla is not None:
            m = self.mla
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * (m.d_nope + m.d_rope)
                + d * (m.kv_lora_rank + m.d_rope)
                + m.kv_lora_rank * self.n_heads * (m.d_nope + m.d_v)
                + self.n_heads * m.d_v * d
            )
        else:
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head
            attn += self.n_heads * self.d_head * d
        if self.moe is not None:
            moe = self.moe
            dense_layers = moe.first_k_dense
            moe_layers = L - dense_layers
            ff = dense_layers * 3 * d * (moe.d_ff_dense or self.d_ff)
            ff += moe_layers * (
                (moe.n_experts + moe.n_shared) * 3 * d * moe.d_ff_expert
                + d * moe.n_experts
            )
        else:
            mult = 3 if self.act in ("swiglu", "geglu") else 2
            ff = L * mult * d * self.d_ff
        return emb + L * attn + ff

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed-to experts)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        moe = self.moe
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.mla is not None:
            m = self.mla
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * (m.d_nope + m.d_rope)
                + d * (m.kv_lora_rank + m.d_rope)
                + m.kv_lora_rank * self.n_heads * (m.d_nope + m.d_v)
                + self.n_heads * m.d_v * d
            )
        else:
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head
            attn += self.n_heads * self.d_head * d
        dense_layers = moe.first_k_dense
        moe_layers = L - dense_layers
        ff = dense_layers * 3 * d * (moe.d_ff_dense or self.d_ff)
        ff += moe_layers * (moe.top_k + moe.n_shared) * 3 * d * moe.d_ff_expert
        return emb + L * attn + ff


# -------------------------------------------------------------------- GNN
@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                        # gatedgcn | egnn | gin | meshgraphnet
    n_layers: int
    d_hidden: int
    d_in: int = 64                   # input feature dim (overridden per shape)
    d_edge: int = 0
    n_classes: int = 16
    aggregator: str = "sum"
    mlp_layers: int = 2              # meshgraphnet per-MLP depth
    learnable_eps: bool = True       # GIN-ε
    task: str = "node"               # node | graph | regression
    dtype: str = "float32"


# ----------------------------------------------------------------- RecSys
@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    n_sparse: int = 39
    embed_dim: int = 10
    vocab_per_field: int = 1_000_000
    cin_layers: tuple[int, ...] = (200, 200, 200)
    mlp_layers: tuple[int, ...] = (400, 400)
    bag_size: int = 1                # multi-hot bag length (EmbeddingBag)
    dtype: str = "float32"


# --------------------------------------------------------------- registry
@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    family: str                             # lm | gnn | recsys | stwig
    config: Any
    smoke: Callable[[], Any]                # reduced config for CPU smoke
    shapes: tuple[str, ...]                 # assigned input-shape ids
    skipped_shapes: tuple[tuple[str, str], ...] = ()  # (shape, reason)


_REGISTRY: dict[str, ArchEntry] = {}


def register(entry: ArchEntry) -> ArchEntry:
    _REGISTRY[entry.arch_id] = entry
    return entry


def get(arch_id: str) -> ArchEntry:
    if arch_id not in _REGISTRY:
        import repro.configs  # noqa: F401  (populate registry)
    return _REGISTRY[arch_id]


def all_archs() -> dict[str, ArchEntry]:
    import repro.configs  # noqa: F401

    return dict(_REGISTRY)
