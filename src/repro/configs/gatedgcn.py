"""gatedgcn [gnn]: 16L d_hidden=70 gated aggregator [arXiv:2003.00982]."""
from repro.configs.base import ArchEntry, GNNConfig, register

CONFIG = GNNConfig(
    name="gatedgcn", kind="gatedgcn", n_layers=16, d_hidden=70,
    d_edge=8, aggregator="gated", n_classes=16,
)


def smoke() -> GNNConfig:
    return GNNConfig(
        name="gatedgcn-smoke", kind="gatedgcn", n_layers=3, d_hidden=16,
        d_in=8, d_edge=4, aggregator="gated", n_classes=5,
    )


ENTRY = register(
    ArchEntry(
        arch_id="gatedgcn", family="gnn", config=CONFIG, smoke=smoke,
        shapes=("full_graph_sm", "minibatch_lg", "ogb_products", "molecule"),
    )
)
