"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000
— GeGLU, head_dim=256, tied embeddings, scaled embed [arXiv:2403.08295; hf]."""
from repro.configs.base import ArchEntry, LMConfig, register

CONFIG = LMConfig(
    name="gemma-2b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab_size=256000,
    act="geglu",
    tie_embeddings=True,
    emb_scale=True,
    remat="block",
)


def smoke() -> LMConfig:
    return LMConfig(
        name="gemma-2b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_head=32,
        d_ff=128,
        vocab_size=256,
        act="geglu",
        tie_embeddings=True,
        emb_scale=True,
        dtype="float32",
    )


ENTRY = register(
    ArchEntry(
        arch_id="gemma-2b",
        family="lm",
        config=CONFIG,
        smoke=smoke,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skipped_shapes=(
            ("long_500k", "pure full-attention arch (no sub-quadratic mechanism); skipped per brief"),
        ),
    )
)
