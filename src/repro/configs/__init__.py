"""Architecture registry — importing this package registers all configs."""
from repro.configs import base
from repro.configs import (  # noqa: F401  (registration side effects)
    deepseek_v3_671b,
    egnn,
    gatedgcn,
    gemma_2b,
    gin_tu,
    meshgraphnet,
    mixtral_8x22b,
    qwen1_5_110b,
    qwen2_72b,
    stwig,
    xdeepfm,
)
from repro.configs.base import ArchEntry, all_archs, get

__all__ = ["base", "ArchEntry", "all_archs", "get"]
