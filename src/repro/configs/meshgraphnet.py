"""meshgraphnet [gnn]: 15L d_hidden=128 sum aggregator mlp_layers=2
[arXiv:2010.03409; unverified]."""
from repro.configs.base import ArchEntry, GNNConfig, register

CONFIG = GNNConfig(
    name="meshgraphnet", kind="meshgraphnet", n_layers=15, d_hidden=128,
    d_edge=8, aggregator="sum", mlp_layers=2, task="regression", n_classes=1,
)


def smoke() -> GNNConfig:
    return GNNConfig(
        name="meshgraphnet-smoke", kind="meshgraphnet", n_layers=2,
        d_hidden=16, d_in=8, d_edge=4, mlp_layers=2, task="regression",
        n_classes=1,
    )


ENTRY = register(
    ArchEntry(
        arch_id="meshgraphnet", family="gnn", config=CONFIG, smoke=smoke,
        shapes=("full_graph_sm", "minibatch_lg", "ogb_products", "molecule"),
    )
)
