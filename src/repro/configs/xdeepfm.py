"""xdeepfm [recsys]: 39 sparse fields, embed_dim=10, CIN 200-200-200,
MLP 400-400 [arXiv:1803.05170]."""
from repro.configs.base import ArchEntry, RecSysConfig, register

CONFIG = RecSysConfig(
    name="xdeepfm",
    n_sparse=39,
    embed_dim=10,
    vocab_per_field=1_000_000,
    cin_layers=(200, 200, 200),
    mlp_layers=(400, 400),
    bag_size=4,
)


def smoke() -> RecSysConfig:
    return RecSysConfig(
        name="xdeepfm-smoke",
        n_sparse=6,
        embed_dim=8,
        vocab_per_field=100,
        cin_layers=(10, 10),
        mlp_layers=(16, 16),
        bag_size=3,
    )


ENTRY = register(
    ArchEntry(
        arch_id="xdeepfm", family="recsys", config=CONFIG, smoke=smoke,
        shapes=("train_batch", "serve_p99", "serve_bulk", "retrieval_cand"),
    )
)
