"""The paper's own system config: graph workload + engine capacities."""
import dataclasses

from repro.configs.base import ArchEntry, register


@dataclasses.dataclass(frozen=True)
class STwigConfig:
    name: str = "stwig"
    n_nodes: int = 64_000_000          # paper default (§6.3): 64M nodes
    avg_degree: int = 16
    n_labels: int = 418                # US-Patents label count
    label_zipf: float = 0.0
    n_shards: int = 256
    query_nodes: int = 10              # §6.1 defaults
    query_edges: int = 20
    max_matches: int = 1024            # pipeline termination


CONFIG = STwigConfig()


def smoke() -> STwigConfig:
    return STwigConfig(
        name="stwig-smoke", n_nodes=2_000, avg_degree=8, n_labels=8,
        n_shards=4, query_nodes=6, query_edges=8,
    )


ENTRY = register(
    ArchEntry(
        arch_id="stwig", family="stwig", config=CONFIG, smoke=smoke,
        shapes=("paper_default",),
    )
)
