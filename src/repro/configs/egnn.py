"""egnn [gnn]: 4L d_hidden=64 E(n)-equivariant [arXiv:2102.09844]."""
from repro.configs.base import ArchEntry, GNNConfig, register

CONFIG = GNNConfig(
    name="egnn", kind="egnn", n_layers=4, d_hidden=64, n_classes=16,
)


def smoke() -> GNNConfig:
    return GNNConfig(
        name="egnn-smoke", kind="egnn", n_layers=2, d_hidden=16, d_in=8,
        n_classes=5,
    )


ENTRY = register(
    ArchEntry(
        arch_id="egnn", family="gnn", config=CONFIG, smoke=smoke,
        shapes=("full_graph_sm", "minibatch_lg", "ogb_products", "molecule"),
    )
)
