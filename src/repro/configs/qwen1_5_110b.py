"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias [hf:Qwen/Qwen1.5-110B; hf]."""
from repro.configs.base import ArchEntry, LMConfig, register

CONFIG = LMConfig(
    name="qwen1.5-110b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    remat="block",
)


def smoke() -> LMConfig:
    return LMConfig(
        name="qwen1.5-110b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=192,
        vocab_size=256,
        qkv_bias=True,
        dtype="float32",
    )


ENTRY = register(
    ArchEntry(
        arch_id="qwen1.5-110b",
        family="lm",
        config=CONFIG,
        smoke=smoke,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skipped_shapes=(
            ("long_500k", "pure full-attention arch (no sub-quadratic mechanism); skipped per brief"),
        ),
    )
)
