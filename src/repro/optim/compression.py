"""Gradient compression for cross-pod data parallelism.

int8 block-quantized all-reduce with error feedback (1-bit-Adam-style EF):
each worker quantizes (grad + carried error), all-reduces the int8 payload
(summed in int32), dequantizes with the max scale, and carries the
quantization residual into the next step. Cross-pod links are the scarcest
bandwidth at 512+ chips; this cuts DP gradient bytes 4×.

Used under shard_map (explicit collectives); the pjit trainer keeps XLA's
native f32/bf16 psum unless `--grad-compression` opts in.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size


class EFState(NamedTuple):
    error: jnp.ndarray  # f32 residual carried between steps


def ef_init(param: jnp.ndarray) -> EFState:
    return EFState(error=jnp.zeros(param.shape, jnp.float32))


def compressed_psum(
    g: jnp.ndarray,
    ef: EFState,
    axis_name: str,
) -> tuple[jnp.ndarray, EFState]:
    """Returns (mean-reduced gradient, new error-feedback state)."""
    n = axis_size(axis_name)
    x = g.astype(jnp.float32) + ef.error
    absmax = jnp.max(jnp.abs(x))
    # shared scale across workers so int8 payloads sum correctly
    scale = lax.pmax(jnp.maximum(absmax / 127.0, 1e-12), axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    err = x - q.astype(jnp.float32) * scale  # local residual
    summed = lax.psum(q.astype(jnp.int32), axis_name)
    out = summed.astype(jnp.float32) * scale / n
    return out.astype(g.dtype), EFState(error=err)


def compress_tree(grads, ef_tree, axis_name: str):
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_tree)
    outs = [compressed_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in outs]), tdef.unflatten(
        [o[1] for o in outs]
    )
