"""AdamW in pure JAX with optional int8-quantized moments.

At 512+ chips the optimizer state dominates HBM for the big LMs; storing m/v
as int8 with a per-row f32 scale (block-wise absmax quantization, error kept
implicitly by requantization) cuts state bytes 4× — one of the
distributed-optimization tricks recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False


class QTensor(NamedTuple):
    q: jnp.ndarray       # int8 payload
    scale: jnp.ndarray   # f32 per-row scale (last-dim blocks)


def _quant(x: jnp.ndarray, *, sqrt_domain: bool = False) -> QTensor:
    if sqrt_domain:
        # v >= 0: quantizing sqrt(v) compresses the dynamic range so small
        # second moments never collapse to zero (which would blow up m/√v)
        x = jnp.sqrt(jnp.maximum(x, 0.0))
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    return QTensor((x / scale).round().astype(jnp.int8), scale.astype(jnp.float32))


def _dequant(t: QTensor, *, sqrt_domain: bool = False) -> jnp.ndarray:
    x = t.q.astype(jnp.float32) * t.scale
    return jnp.square(x) if sqrt_domain else x


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(cfg: AdamWConfig, params) -> AdamWState:
    def zeros_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quant(z) if cfg.quantize_moments and p.ndim >= 1 else z

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros_like, params),
        v=jax.tree.map(zeros_like, params),  # v stored in sqrt domain
    )


def global_norm(grads) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )


def update(
    cfg: AdamWConfig,
    grads,
    state: AdamWState,
    params,
    lr_scale: jnp.ndarray | float = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    is_q = lambda t: isinstance(t, QTensor)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _dequant(m) if is_q(m) else m
        v_f = _dequant(v, sqrt_domain=True) if is_q(v) else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd_ = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        if cfg.quantize_moments:
            # quantized moments can momentarily under-estimate v: clamp the
            # per-element step (trust-region guard, standard for 8-bit Adam)
            upd_ = jnp.clip(upd_, -10.0, 10.0)
        new_p = p.astype(jnp.float32) - lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32))
        return (
            new_p.astype(p.dtype),
            _quant(m_f) if is_q(m) else m_f,
            _quant(v_f, sqrt_domain=True) if is_q(v) else v_f,
        )

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(state.m, is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(state.v, is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics


def cosine_warmup(step, *, warmup: int, total: int, floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
