from repro.optim.adamw import AdamWConfig, AdamWState, cosine_warmup, init, update
from repro.optim import compression

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "cosine_warmup",
    "init",
    "update",
    "compression",
]
