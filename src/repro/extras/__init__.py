"""Quarantined seed scaffolding (staticcheck `orphan-module` boundary).

Modules here are runnable but unreachable from every test, benchmark,
example and script — kept for reference (production launch dry-runs, the
training launcher, model shape tables) rather than deleted outright. The
architecture lint exempts this directory from the orphan rule; everything
else under ``src/`` must stay reachable or move here. Promote a module back
out by giving it a consumer (a test or a declared entry point) first.
"""
