"""Jitted public wrapper: picks the Pallas kernel on TPU, the chunked-jnp
path elsewhere (and in interpret-mode validation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.extras.flash_attention.flash_attention import flash_attention
from repro.extras.flash_attention.ref import mha_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "softcap", "use_pallas", "interpret")
)
def attention_fwd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    scale: float | None = None,
    window: int | None = None,
    softcap: float | None = None,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return flash_attention(
            q, k, v, scale=scale, window=window, softcap=softcap,
            interpret=interpret,
        )
    return mha_reference(q, k, v, scale=scale, window=window, softcap=softcap)
