from repro.extras.flash_attention.flash_attention import flash_attention
from repro.extras.flash_attention.ops import attention_fwd
from repro.extras.flash_attention.ref import mha_reference

__all__ = ["flash_attention", "attention_fwd", "mha_reference"]
