"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mha_reference(
    q: jnp.ndarray,   # (B, Sq, Nq, H)
    k: jnp.ndarray,   # (B, Skv, Nkv, H)
    v: jnp.ndarray,   # (B, Skv, Nkv, Hv)
    *,
    scale: float | None = None,
    window: int | None = None,
    softcap: float | None = None,
) -> jnp.ndarray:
    B, Sq, Nq, H = q.shape
    _, Skv, Nkv, Hv = v.shape
    G = Nq // Nkv
    scale = scale if scale is not None else H**-0.5
    qg = q.reshape(B, Sq, Nkv, G, H).astype(jnp.float32)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    pos_q = np.arange(Sq)[:, None]
    pos_k = np.arange(Skv)[None, :]
    ok = pos_k <= pos_q
    if window is not None:
        ok = ok & (pos_k > pos_q - window)
    logits = jnp.where(jnp.asarray(ok)[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Nq, Hv).astype(q.dtype)
