"""Pallas TPU flash attention (forward), GQA + causal + sliding window.

Tiling: grid = (batch, kv_head, q_group, Sq/BQ, Skv/BK); the kv axis is the
innermost (sequential) dimension so the online-softmax state (m, l, acc)
lives in VMEM scratch across kv steps. Block shapes are MXU-aligned
(BQ/BK multiples of 128 when the sequence allows; the head dim is the lane
dimension).

Validated in interpret mode against ``ref.mha_reference`` (which is itself
cross-checked with ``repro.models.layers.attention``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref,    # (1, BQ, 1, 1, H)
    k_ref,    # (1, BK, 1, H)
    v_ref,    # (1, BK, 1, Hv)
    o_ref,    # (1, BQ, 1, 1, Hv)
    m_ref,    # scratch (BQ,)
    l_ref,    # scratch (BQ,)
    acc_ref,  # scratch (BQ, Hv)
    *,
    bq: int,
    bk: int,
    scale: float,
    window: int | None,
    softcap: float | None,
    kv_steps: int,
):
    qi = pl.program_id(3)
    ki = pl.program_id(4)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, 0, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (BQ, BK)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap

    pos_q = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    pos_k = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p, v_ref[0, :, 0, :].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        o_ref[0, :, 0, 0, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,   # (B, Sq, Nq, H)
    k: jnp.ndarray,   # (B, Skv, Nkv, H)
    v: jnp.ndarray,   # (B, Skv, Nkv, Hv)
    *,
    scale: float | None = None,
    window: int | None = None,
    softcap: float | None = None,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Causal GQA flash attention. Sq == Skv (training/prefill shape)."""
    B, Sq, Nq, H = q.shape
    _, Skv, Nkv, Hv = v.shape
    assert Sq == Skv, "training kernel: square attention"
    G = Nq // Nkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    while Sq % bq:
        bq //= 2
    while Skv % bk:
        bk //= 2
    kv_steps = Skv // bk
    scale = scale if scale is not None else H**-0.5

    qg = q.reshape(B, Sq, Nkv, G, H)
    grid = (B, Nkv, G, Sq // bq, kv_steps)

    kernel = functools.partial(
        _attn_kernel,
        bq=bq,
        bk=bk,
        scale=scale,
        window=window,
        softcap=softcap,
        kv_steps=kv_steps,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, 1, H), lambda b, n, g, i, j: (b, i, n, g, 0)),
            pl.BlockSpec((1, bk, 1, H), lambda b, n, g, i, j: (b, j, n, 0)),
            pl.BlockSpec((1, bk, 1, Hv), lambda b, n, g, i, j: (b, j, n, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, bq, 1, 1, Hv), lambda b, n, g, i, j: (b, i, n, g, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Nkv, G, Hv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Hv), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(B, Sq, Nq, Hv)
