"""Pallas TPU kernel for GNN message aggregation (segment-sum over a sorted
edge→node scatter) — the SpMM-regime hot path (taxonomy §GNN).

Edges arrive sorted by destination (the graphstore CSR guarantees it).
Per edge block the kernel computes each edge's *rank* — the number of edges
in the block with a strictly smaller destination (equal destinations share a
rank) — via one (BE×BE) comparison matrix, then contracts the rank one-hot
against the message block on the MXU. That compacts every distinct
destination in the block to one partial row regardless of how sparse the
node ids are. A second one-hot contraction recovers each rank's node id.
Partials from different blocks may target the same node (segments straddle
block boundaries), so a cheap XLA epilogue scatter-adds the
(n_blocks · BE, d) partials — O(E) work total, one pass over messages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mp_kernel(msg_ref, dst_ref, out_ref, nid_ref, *, be: int, sentinel: int):
    msg = msg_ref[...]                    # (BE, d)
    dst = dst_ref[...]                    # (BE,) int32 sorted ascending
    # rank[i] = #edges with strictly smaller dst (ties share a rank)
    smaller = dst[:, None] > dst[None, :]             # (BE, BE)
    rank = jnp.sum(smaller.astype(jnp.int32), axis=1)  # (BE,)
    onehot = (
        rank[None, :] == jax.lax.broadcasted_iota(jnp.int32, (be, be), 0)
    ).astype(msg.dtype)                   # (BE rows, BE edges)
    out_ref[0] = jax.lax.dot_general(
        onehot, msg, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)               # (BE, d) partial sums per rank
    cnt = jnp.sum(onehot, axis=1)
    nid_sum = jnp.sum(onehot * dst[None, :].astype(msg.dtype), axis=1)
    nid = jnp.where(cnt > 0, nid_sum / jnp.maximum(cnt, 1.0), sentinel)
    nid_ref[0] = nid.astype(jnp.int32)


def segment_mp_partials(
    messages: jnp.ndarray,   # (E, d) — already-masked edge messages
    dst_sorted: jnp.ndarray,  # (E,) int32 ascending destination ids
    n_nodes: int,
    *,
    be: int = 256,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (partials (n_blocks, BE, d), nids (n_blocks, BE))."""
    E, d = messages.shape
    be = min(be, E)
    while E % be:
        be //= 2
    nb = E // be
    out, nid = pl.pallas_call(
        functools.partial(_mp_kernel, be=be, sentinel=n_nodes),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((be, d), lambda i: (i, 0)),
            pl.BlockSpec((be,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1, be, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, be), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, be, d), messages.dtype),
            jax.ShapeDtypeStruct((nb, be), jnp.int32),
        ],
        interpret=interpret,
    )(messages, dst_sorted)
    return out, nid


def segment_mp(
    messages: jnp.ndarray,
    dst_sorted: jnp.ndarray,
    n_nodes: int,
    *,
    be: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Full fused segment-sum: Pallas partial pass + XLA scatter epilogue."""
    partials, nids = segment_mp_partials(
        messages, dst_sorted, n_nodes, be=be, interpret=interpret
    )
    nb, bn, d = partials.shape
    out = jnp.zeros((n_nodes, d), messages.dtype)
    return out.at[nids.reshape(-1)].add(
        partials.reshape(nb * bn, d), mode="drop"
    )
