"""Oracle: plain segment_sum (the exact op the models use)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_mp_reference(
    messages: jnp.ndarray, dst: jnp.ndarray, n_nodes: int
) -> jnp.ndarray:
    return jax.ops.segment_sum(messages, dst, num_segments=n_nodes)
