"""Jitted wrapper: Pallas on TPU (sorted edges), segment_sum elsewhere."""
from __future__ import annotations

import functools

import jax

from repro.extras.segment_mp import ref
from repro.extras.segment_mp import segment_mp as k


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("n_nodes", "use_pallas", "interpret"))
def aggregate(messages, dst_sorted, *, n_nodes, use_pallas=None, interpret=False):
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return k.segment_mp(
            messages, dst_sorted, n_nodes, interpret=interpret
        )
    return ref.segment_mp_reference(messages, dst_sorted, n_nodes)
