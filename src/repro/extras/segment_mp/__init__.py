from repro.extras.segment_mp.segment_mp import segment_mp, segment_mp_partials
from repro.extras.segment_mp import ops, ref

__all__ = ["segment_mp", "segment_mp_partials", "ops", "ref"]
