"""Per-cell input specs for the multi-pod dry-run.

Every (architecture × assigned input shape) cell defines:
  * ``fn(cfg)``            — the step function that gets lowered
                             (train_step / prefill / decode / serve / retrieve)
  * ``abstract_args(cfg)`` — ShapeDtypeStruct stand-ins (never allocated)
  * ``arg_axes(cfg)``      — logical axis names per leaf, mapped to mesh axes
                             by the active rule set (launch/rules.py)
  * ``kind``               — which rule set variant applies

Sharded dims are padded to multiples of 512 (the multi-pod chip count) so
both meshes divide them; padding semantics are carried by the masks that all
models already take.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import ArchEntry, GNNConfig, LMConfig
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tf
from repro.models.schema import _flatten, abstract_params
from repro.train.step import make_train_step

F32, I32, BOOL = jnp.float32, jnp.int32, jnp.bool_


def _pad(n: int, mult: int = 512) -> int:
    return mult * math.ceil(n / mult)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------- schema ax
def schema_axes(schema) -> dict:
    out: dict = {}
    for path, d in _flatten(schema):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = d.axes
    return out


def opt_abstract_and_axes(schema, opt_cfg: optim.AdamWConfig):
    params_abs = abstract_params(schema)
    axes = schema_axes(schema)
    state_abs = jax.eval_shape(lambda p: optim.init(opt_cfg, p), params_abs)

    def moment_axes(a):
        if opt_cfg.quantize_moments:
            return optim.adamw.QTensor(q=a, scale=tuple(a[:-1]) + (None,))
        return a

    state_axes = optim.AdamWState(
        step=(),
        m=jax.tree.map(
            moment_axes, axes, is_leaf=lambda x: isinstance(x, tuple)
        ),
        v=jax.tree.map(
            moment_axes, axes, is_leaf=lambda x: isinstance(x, tuple)
        ),
    )
    return state_abs, state_axes


@dataclasses.dataclass
class CellDef:
    arch_id: str
    shape_id: str
    kind: str                      # rule-set variant
    fn: Callable                   # (cfg, opt_cfg) -> step callable
    abstract_args: Callable        # (cfg, opt_cfg) -> tuple pytree
    arg_axes: Callable             # (cfg, opt_cfg) -> tuple pytree of axes
    donate: tuple[int, ...] = ()
    note: str = ""


# -------------------------------------------------------------------- LM
LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode_long", seq=524288, batch=1),
}


def _lm_cache_abstract(cfg: LMConfig, batch: int, s_cap: int):
    dt = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        data = (
            _sds((L, batch, s_cap, m.kv_lora_rank), dt),
            _sds((L, batch, s_cap, m.d_rope), dt),
        )
        axes = (
            ("layer", "batch", "kv_seq", None),
            ("layer", "batch", "kv_seq", None),
        )
    else:
        sh = (L, batch, s_cap, cfg.n_kv_heads, cfg.d_head)
        data = (_sds(sh, dt), _sds(sh, dt))
        axes = (("layer", "batch", "kv_seq", "kv_heads", None),) * 2
    kind = "mla" if cfg.mla is not None else "gqa"
    return (
        tf.DecodeCache(data, kind, s_cap, False),
        tf.DecodeCache(axes, kind, s_cap, False),
    )


def lm_cell(entry: ArchEntry, shape_id: str) -> CellDef:
    spec = LM_SHAPES[shape_id]
    kind = spec["kind"]
    cfg: LMConfig = entry.config

    if kind == "train":
        def fn(cfg, opt_cfg):
            return make_train_step(cfg, opt_cfg)

        def abstract_args(cfg, opt_cfg):
            from repro.models.transformer import lm_schema

            sch = lm_schema(cfg)
            state_abs, _ = opt_abstract_and_axes(sch, opt_cfg)
            return (
                abstract_params(sch),
                state_abs,
                {"tokens": _sds((spec["batch"], spec["seq"]), I32)},
                _sds((), I32),
            )

        def arg_axes(cfg, opt_cfg):
            from repro.models.transformer import lm_schema

            sch = lm_schema(cfg)
            _, state_axes = opt_abstract_and_axes(sch, opt_cfg)
            return (
                schema_axes(sch),
                state_axes,
                {"tokens": ("batch", "seq")},
                (),
            )

        return CellDef(entry.arch_id, shape_id, kind, fn, abstract_args, arg_axes, donate=(0, 1))

    if kind == "prefill":
        def fn(cfg, opt_cfg):
            return lambda params, tokens: tf.prefill(cfg, params, tokens)

        def abstract_args(cfg, opt_cfg):
            from repro.models.transformer import lm_schema

            return (
                abstract_params(lm_schema(cfg)),
                _sds((spec["batch"], spec["seq"]), I32),
            )

        def arg_axes(cfg, opt_cfg):
            from repro.models.transformer import lm_schema

            return (schema_axes(lm_schema(cfg)), ("batch", "seq"))

        return CellDef(entry.arch_id, shape_id, kind, fn, abstract_args, arg_axes)

    # decode / decode_long
    def fn(cfg, opt_cfg):
        return lambda params, cache, token, pos: tf.decode_step(
            cfg, params, cache, token, pos
        )

    def abstract_args(cfg, opt_cfg):
        from repro.models.transformer import lm_schema

        cache_abs, _ = _lm_cache_abstract(cfg, spec["batch"], spec["seq"])
        return (
            abstract_params(lm_schema(cfg)),
            cache_abs,
            _sds((spec["batch"], 1), I32),
            _sds((), I32),
        )

    def arg_axes(cfg, opt_cfg):
        from repro.models.transformer import lm_schema

        _, cache_axes = _lm_cache_abstract(cfg, spec["batch"], spec["seq"])
        return (
            schema_axes(lm_schema(cfg)),
            cache_axes,
            ("batch", None),
            (),
        )

    return CellDef(entry.arch_id, shape_id, kind, fn, abstract_args, arg_axes, donate=(1,))


# ------------------------------------------------------------------- GNN
GNN_SHAPES = {
    "full_graph_sm": dict(n=2708, e=10556, d=1433, task="node"),
    "minibatch_lg": dict(n=170624, e=168960, d=602, task="node"),
    "ogb_products": dict(n=2449029, e=61859140, d=100, task="node"),
    "molecule": dict(n=3840, e=8192, d=32, task="graph", n_graphs=128),
}


def _gnn_batch_abstract(cfg: GNNConfig, s: dict, *, regression: bool = False):
    N, E = _pad(s["n"]), _pad(s["e"])
    task = s["task"]
    g = gnn_lib.GraphBatch(
        node_feat=_sds((N, s["d"]), F32),
        edge_src=_sds((E,), I32),
        edge_dst=_sds((E,), I32),
        node_mask=_sds((N,), BOOL),
        edge_mask=_sds((E,), BOOL),
        edge_feat=_sds((E, cfg.d_edge), F32) if cfg.d_edge else None,
        node_pos=_sds((N, 3), F32) if cfg.kind == "egnn" else None,
        graph_id=_sds((N,), I32) if task == "graph" else None,
        n_graphs=s.get("n_graphs", 1),
        labels=_sds(
            (s.get("n_graphs", N) if task == "graph" else N,),
            F32 if regression else I32,
        ),
        label_mask=_sds((N,), BOOL) if task != "graph" else None,
    )
    ax = gnn_lib.GraphBatch(
        node_feat=("nodes", "feat"),
        edge_src=("edges",),
        edge_dst=("edges",),
        node_mask=("nodes",),
        edge_mask=("edges",),
        edge_feat=("edges", None) if cfg.d_edge else None,
        node_pos=("nodes", None) if cfg.kind == "egnn" else None,
        graph_id=("nodes",) if task == "graph" else None,
        n_graphs=s.get("n_graphs", 1),
        labels=("graph_batch",) if task == "graph" else ("nodes",),
        label_mask=("nodes",) if task != "graph" else None,
    )
    return g, ax


def gnn_cell(entry: ArchEntry, shape_id: str) -> CellDef:
    s = GNN_SHAPES[shape_id]
    base_cfg: GNNConfig = entry.config
    regression = base_cfg.task == "regression"
    # the shape dictates input dim and pooling level; the arch dictates the
    # loss kind (float labels → MSE, incl. graph-level regression)
    task = "graph" if s["task"] == "graph" else (
        "regression" if regression else "node"
    )

    def adapt(cfg: GNNConfig) -> GNNConfig:
        return dataclasses.replace(cfg, d_in=s["d"], task=task)

    def fn(cfg, opt_cfg):
        return make_train_step(adapt(cfg), opt_cfg)

    def abstract_args(cfg, opt_cfg):
        c = adapt(cfg)
        sch = gnn_lib.gnn_schema(c)
        state_abs, _ = opt_abstract_and_axes(sch, opt_cfg)
        g, _ = _gnn_batch_abstract(c, s, regression=regression)
        return (abstract_params(sch), state_abs, {"graph": g}, _sds((), I32))

    def arg_axes(cfg, opt_cfg):
        c = adapt(cfg)
        sch = gnn_lib.gnn_schema(c)
        _, state_axes = opt_abstract_and_axes(sch, opt_cfg)
        _, ax = _gnn_batch_abstract(c, s, regression=regression)
        return (schema_axes(sch), state_axes, {"graph": ax}, ())

    return CellDef(entry.arch_id, shape_id, "train", fn, abstract_args, arg_axes, donate=(0, 1))


# ---------------------------------------------------------------- recsys
REC_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieve", batch=1, n_candidates=1_000_000),
}


def recsys_cell(entry: ArchEntry, shape_id: str) -> CellDef:
    s = REC_SHAPES[shape_id]
    kind = s["kind"]

    def ids_abs(cfg, b):
        return (
            _sds((b, cfg.n_sparse, cfg.bag_size), I32),
            _sds((b, cfg.n_sparse, cfg.bag_size), BOOL),
        )

    ids_ax = (("batch", "field", None), ("batch", "field", None))

    if kind == "train":
        def fn(cfg, opt_cfg):
            return make_train_step(cfg, opt_cfg)

        def abstract_args(cfg, opt_cfg):
            sch = recsys_lib.recsys_schema(cfg)
            state_abs, _ = opt_abstract_and_axes(sch, opt_cfg)
            ids, mask = ids_abs(cfg, s["batch"])
            return (
                abstract_params(sch),
                state_abs,
                {"ids": ids, "bag_mask": mask, "labels": _sds((s["batch"],), I32)},
                _sds((), I32),
            )

        def arg_axes(cfg, opt_cfg):
            sch = recsys_lib.recsys_schema(cfg)
            _, state_axes = opt_abstract_and_axes(sch, opt_cfg)
            return (
                schema_axes(sch),
                state_axes,
                {"ids": ids_ax[0], "bag_mask": ids_ax[1], "labels": ("batch",)},
                (),
            )

        return CellDef(entry.arch_id, shape_id, kind, fn, abstract_args, arg_axes, donate=(0, 1))

    if kind == "serve":
        def fn(cfg, opt_cfg):
            return lambda params, ids, mask: recsys_lib.forward(cfg, params, ids, mask)

        def abstract_args(cfg, opt_cfg):
            ids, mask = ids_abs(cfg, s["batch"])
            return (abstract_params(recsys_lib.recsys_schema(cfg)), ids, mask)

        def arg_axes(cfg, opt_cfg):
            return (schema_axes(recsys_lib.recsys_schema(cfg)),) + ids_ax

        return CellDef(entry.arch_id, shape_id, kind, fn, abstract_args, arg_axes)

    # retrieval
    def fn(cfg, opt_cfg):
        return lambda params, ids, mask, cand: recsys_lib.retrieval_score(
            cfg, params, ids, mask, cand
        )

    def abstract_args(cfg, opt_cfg):
        ids, mask = ids_abs(cfg, 1)
        return (
            abstract_params(recsys_lib.recsys_schema(cfg)),
            ids,
            mask,
            _sds((_pad(s["n_candidates"]),), I32),
        )

    def arg_axes(cfg, opt_cfg):
        return (
            schema_axes(recsys_lib.recsys_schema(cfg)),
            (None, "field", None),
            (None, "field", None),
            ("candidates",),
        )

    return CellDef(entry.arch_id, shape_id, kind, fn, abstract_args, arg_axes)


# --------------------------------------------------------------- registry
def build_cell(entry: ArchEntry, shape_id: str) -> CellDef:
    if entry.family == "lm":
        return lm_cell(entry, shape_id)
    if entry.family == "gnn":
        return gnn_cell(entry, shape_id)
    if entry.family == "recsys":
        return recsys_cell(entry, shape_id)
    raise ValueError(entry.family)


def all_cells() -> list[CellDef]:
    from repro.configs import all_archs

    cells = []
    for entry in all_archs().values():
        if entry.family in ("lm", "gnn", "recsys"):
            for sh in entry.shapes:
                cells.append(build_cell(entry, sh))
    return cells
