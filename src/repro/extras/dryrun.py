import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402  (the two lines above MUST precede any jax import)
# NOTE: no `from __future__ import annotations` — the XLA_FLAGS lines must
# stay the very first statements in this file.
"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh) cell:
  jit(step).lower(*ShapeDtypeStructs).compile()
on the production meshes — 16×16 (256 chips, single pod) and 2×16×16
(512 chips, 2 pods) — capturing memory_analysis(), cost_analysis() and the
collective mix for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.extras.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.extras.dryrun --all [--multi-pod-only|--single-pod-only]
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
from jax.sharding import NamedSharding

from repro import optim
from repro.analysis import roofline as rl
from repro.configs import all_archs, get
from repro.configs.base import GNNConfig, LMConfig, RecSysConfig
from repro.extras import shapes as shapes_lib
from repro.launch.mesh import make_production_mesh
from repro.launch.rules import make_rules
from repro.launch.sharding import axis_rules, spec_for

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _opt_cfg_for(cfg) -> optim.AdamWConfig:
    # int8 moments for the models whose optimizer state would not fit HBM
    quant = isinstance(cfg, LMConfig) and cfg.n_params() > 1e11
    return optim.AdamWConfig(quantize_moments=quant)


def _model_flops(entry, cell, cfg) -> float:
    if isinstance(cfg, LMConfig):
        s = shapes_lib.LM_SHAPES[cell.shape_id]
        return rl.model_flops_lm(cfg, s["batch"], s["seq"], cell.kind)
    if isinstance(cfg, GNNConfig):
        s = shapes_lib.GNN_SHAPES[cell.shape_id]
        return rl.model_flops_gnn(cfg, s["n"], s["e"])
    if isinstance(cfg, RecSysConfig):
        s = shapes_lib.REC_SHAPES[cell.shape_id]
        return rl.model_flops_recsys(cfg, s.get("batch", 1), cell.kind)
    return 0.0


def _compile_cell(entry, cell, cfg, opt_cfg, mesh):
    """Shared lower+compile for a (possibly size-reduced) config."""
    rules = make_rules(cfg, cell.kind, mesh)
    with axis_rules(mesh, rules):
        args = cell.abstract_args(cfg, opt_cfg)
        axes = cell.arg_axes(cfg, opt_cfg)

        def _is_axes(x):
            return isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x
            )

        def _to_sharding(a):
            from jax.sharding import PartitionSpec as P

            spec = spec_for(a) if _is_axes(a) else P()
            return NamedSharding(mesh, spec)

        in_shardings = jax.tree.map(_to_sharding, axes, is_leaf=_is_axes)
        fn = cell.fn(cfg, opt_cfg)
        jitted = jax.jit(fn, in_shardings=in_shardings)
        lowered = jitted.lower(*args)
        return lowered, lowered.compile()


def _cost_vector(compiled, n_chips) -> dict:
    cost = compiled.cost_analysis()
    coll = rl.parse_collectives(compiled.as_text(), n_chips)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll.total_bytes,
        "coll_by_kind": coll.bytes_by_kind,
        "coll_count": coll.count_by_kind,
    }


def _vec(f, a, b=None):
    """Element-wise combine of cost vectors (scalar fields + coll_by_kind)."""
    out = {}
    for k in ("flops", "bytes", "coll"):
        out[k] = f(a[k], b[k] if b is not None else None)
    kinds = set(a["coll_by_kind"]) | (set(b["coll_by_kind"]) if b else set())
    out["coll_by_kind"] = {
        kk: f(
            a["coll_by_kind"].get(kk, 0.0),
            b["coll_by_kind"].get(kk, 0.0) if b is not None else None,
        )
        for kk in kinds
    }
    out["coll_count"] = a.get("coll_count", {})
    return out


def _add(a, b):
    return _vec(lambda x, y: x + y, a, b)


def _sub(a, b):
    return _vec(lambda x, y: x - y, a, b)


def _scale(a, s):
    return _vec(lambda x, _: x * s, a)


def _shrink(cfg, n_layers: int, first_k_dense: int | None = None):
    if isinstance(cfg, LMConfig):
        moe = cfg.moe
        if moe is not None and first_k_dense is not None:
            moe = dataclasses.replace(moe, first_k_dense=first_k_dense)
        return dataclasses.replace(cfg, n_layers=n_layers, moe=moe)
    return dataclasses.replace(cfg, n_layers=n_layers)


def measure_cost(entry, shape_id: str, cfg, opt_cfg, mesh) -> dict:
    """Per-device cost, exact in depth: XLA counts scan bodies once, so we
    compile small-depth variants (with attention tile loops unrolled),
    difference out the per-layer marginal cost per stack, and extrapolate
    base + Σ_s L_s · c_s (methodology validated by tests/test_roofline.py)."""
    from repro.models.layers import unrolled_model

    n_chips = int(mesh.devices.size)

    def cost_of(cfg_small):
        cell = shapes_lib.build_cell(
            dataclasses.replace(entry, config=cfg_small), shape_id
        )
        with unrolled_model():
            _, compiled = _compile_cell(entry, cell, cfg_small, opt_cfg, mesh)
        return _cost_vector(compiled, n_chips)

    if isinstance(cfg, LMConfig):
        k = cfg.moe.first_k_dense if cfg.moe is not None else cfg.n_layers
        Lm = cfg.n_layers - k
        if cfg.moe is not None and k > 0 and Lm > 0:
            # two stacks: cost = base + Ld·cd + Lm·cm (3 probes solve it)
            c11 = cost_of(_shrink(cfg, 2, 1))
            c21 = cost_of(_shrink(cfg, 3, 2))
            c12 = cost_of(_shrink(cfg, 3, 1))
            cd = _sub(c21, c11)
            cm = _sub(c12, c11)
            base = _sub(c11, _add(cd, cm))
            return _add(base, _add(_scale(cd, k), _scale(cm, Lm)))
        c1 = cost_of(_shrink(cfg, 1, 0 if cfg.moe is not None else None))
        c2 = cost_of(_shrink(cfg, 2, 0 if cfg.moe is not None else None))
        per = _sub(c2, c1)
        return _add(c1, _scale(per, cfg.n_layers - 1))
    if isinstance(cfg, GNNConfig):
        c1 = cost_of(_shrink(cfg, 1))
        c2 = cost_of(_shrink(cfg, 2))
        return _add(c1, _scale(_sub(c2, c1), cfg.n_layers - 1))
    # recsys: nothing scanned — measure directly
    cell = shapes_lib.build_cell(entry, shape_id)
    _, compiled = _compile_cell(entry, cell, cfg, opt_cfg, mesh)
    return _cost_vector(compiled, n_chips)


def apply_variant(cfg, kind: str, mesh):
    """§Perf optimized variant: grouped MoE dispatch + flash-decoding."""
    from repro.launch.mesh import mesh_axis_size

    if not isinstance(cfg, LMConfig):
        return cfg
    dp = mesh_axis_size(mesh, ("pod", "data"))
    tp = mesh_axis_size(mesh, "model")
    if cfg.moe is not None and kind in ("train", "prefill"):
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=dp)
        )
    if kind in ("prefill", "decode", "decode_long"):
        # replicating TP-sharded weights over DP must fit HBM (16 GiB v5e):
        # bytes/chip = 2·N / (model × experts-over-dp factor). The split-KV
        # decode blocks only pay off together with replicated weights
        # (iteration 2a/2b in EXPERIMENTS.md §Perf), so both gate on fit.
        ep_dp = (
            dp if cfg.moe is not None and cfg.moe.n_experts % (dp * tp) == 0 else 1
        )
        per_chip = 2.0 * cfg.n_params() / (tp * ep_dp)
        if per_chip < 12e9:  # leave room for the KV cache + activations
            cfg = dataclasses.replace(
                cfg,
                inference_param_sharding="tp_replicated",
                decode_kv_blocks=(tp if kind == "decode" else dp * tp)
                if kind != "prefill"
                else 1,
            )
    if kind == "train" and cfg.n_params() < 1e10:
        # small models don't need remat: trade recompute for bytes (§Perf 4)
        cfg = dataclasses.replace(cfg, remat="none")
    return cfg


def run_cell(
    arch_id: str,
    shape_id: str,
    *,
    multi_pod: bool,
    save: bool = True,
    variant: str | None = None,
) -> dict:
    entry = get(arch_id)
    if shape_id in dict(entry.skipped_shapes):
        out = {
            "arch": arch_id, "shape": shape_id,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skipped", "reason": dict(entry.skipped_shapes)[shape_id],
        }
        if save:
            ARTIFACTS.mkdir(parents=True, exist_ok=True)
            (ARTIFACTS / f"{arch_id}__{shape_id}__{out['mesh']}.json").write_text(
                json.dumps(out, indent=2)
            )
        return out
    cell = shapes_lib.build_cell(entry, shape_id)
    cfg = entry.config
    opt_cfg = _opt_cfg_for(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(mesh.devices.size)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    if variant == "opt":
        cfg = apply_variant(cfg, cell.kind, mesh)
        entry = dataclasses.replace(entry, config=cfg)
    out: dict = {
        "arch": arch_id, "shape": shape_id, "mesh": mesh_name,
        "kind": cell.kind, "status": "ok", "variant": variant or "baseline",
    }
    t0 = time.time()
    try:
        # 1. the REQUIRED proof: full config lowers + compiles on this mesh
        lowered, compiled = _compile_cell(entry, cell, cfg, opt_cfg, mesh)
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()

        # 2. exact per-device cost via depth extrapolation (scan-once fix)
        cost = measure_cost(entry, shape_id, cfg, opt_cfg, mesh)
        roof = rl.Roofline(
            flops=cost["flops"],
            hbm_bytes=cost["bytes"],
            collective_bytes=cost["coll"],
            n_chips=n_chips,
            model_flops=_model_flops(entry, cell, cfg),
        )
        coll = rl.CollectiveStats(cost["coll_by_kind"], cost["coll_count"])
        out.update(
            {
                "compile_s": round(t_compile, 1),
                "memory": {
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                    "output_bytes": getattr(mem, "output_size_in_bytes", None),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                    "generated_code_bytes": getattr(
                        mem, "generated_code_size_in_bytes", None
                    ),
                },
                "collectives": {
                    "bytes_by_kind": coll.bytes_by_kind,
                    "count_by_kind": coll.count_by_kind,
                },
                "roofline": roof.to_dict(),
            }
        )
        print(
            f"[OK] {arch_id} × {shape_id} × {mesh_name}: "
            f"compile {t_compile:.0f}s, "
            f"t_comp {roof.t_compute*1e3:.2f}ms t_mem {roof.t_memory*1e3:.2f}ms "
            f"t_coll {roof.t_collective*1e3:.2f}ms → {roof.bottleneck} "
            f"(roofline frac {roof.roofline_fraction:.2f})"
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded bug
        out["status"] = "error"
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {arch_id} × {shape_id} × {mesh_name}: {out['error']}")
    if save:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        suffix = f"__{variant}" if variant else ""
        path = ARTIFACTS / f"{arch_id}__{shape_id}__{mesh_name}{suffix}.json"
        path.write_text(json.dumps(out, indent=2, default=str))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--variant", type=str, default=None, choices=[None, "opt"])
    args = ap.parse_args()

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    results = []
    if args.all:
        for entry in all_archs().values():
            if entry.family not in ("lm", "gnn", "recsys"):
                continue
            for sh in entry.shapes + tuple(s for s, _ in entry.skipped_shapes):
                for mp in meshes:
                    results.append(
                        run_cell(entry.arch_id, sh, multi_pod=mp, variant=args.variant)
                    )
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            results.append(
                run_cell(args.arch, args.shape, multi_pod=mp, variant=args.variant)
            )

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n=== dry-run: {n_ok} ok, {n_skip} skipped, {n_err} failed ===")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
