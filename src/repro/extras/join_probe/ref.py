"""Oracles for the join-probe kernels."""
from __future__ import annotations

import jax.numpy as jnp


def lower_bound_reference(ka_sorted: jnp.ndarray, kb: jnp.ndarray) -> jnp.ndarray:
    return jnp.searchsorted(ka_sorted, kb, side="left").astype(jnp.int32)


def window_reference(ka_sorted, kb, lo, *, dup_cap: int):
    cap_a = ka_sorted.shape[0]
    probe = lo[:, None] + jnp.arange(dup_cap, dtype=jnp.int32)[None, :]
    in_range = probe < cap_a
    pc = jnp.minimum(probe, cap_a - 1)
    vals = jnp.take(ka_sorted, pc)
    return in_range & (vals == kb[:, None]), pc
