from repro.extras.join_probe.join_probe import probe_lower_bound, probe_window
from repro.extras.join_probe import ops, ref

__all__ = ["probe_lower_bound", "probe_window", "ops", "ref"]
