"""Jitted wrappers for the join-probe kernels."""
from __future__ import annotations

import functools

import jax

from repro.extras.join_probe import join_probe as k
from repro.extras.join_probe import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def lower_bound(ka_sorted, kb, *, use_pallas=None, interpret=False):
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return k.probe_lower_bound(ka_sorted, kb, interpret=interpret)
    return ref.lower_bound_reference(ka_sorted, kb)


@functools.partial(jax.jit, static_argnames=("dup_cap", "use_pallas", "interpret"))
def window(ka_sorted, kb, lo, *, dup_cap, use_pallas=None, interpret=False):
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return k.probe_window(ka_sorted, kb, lo, dup_cap=dup_cap, interpret=interpret)
    return ref.window_reference(ka_sorted, kb, lo, dup_cap=dup_cap)
