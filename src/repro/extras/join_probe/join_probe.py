"""Pallas TPU kernel for the sort-merge join probe (paper §4.2 step 3).

Build side: sorted uint32 hash keys (VMEM-resident — join tables are the
paper's memory-bounded pipeline blocks, ≤ a few hundred K rows).
Probe side: tiled key blocks; for each probe key a fully vectorized binary
search (log2(capA) compare/select steps over the resident keys) yields the
run start, then a static window of ``dup_cap`` candidates is emitted as
(hit, a_row) pairs. Exact column verification stays in XLA (it needs the
wide table payloads, which would blow VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(ka_ref, kb_ref, lo_ref, *, cap_a: int, steps: int):
    ka = ka_ref[...]                 # (capA,) uint32 sorted
    kb = kb_ref[...]                 # (BB,) uint32
    bb = kb.shape[0]
    lo = jnp.zeros((bb,), jnp.int32)
    hi = jnp.full((bb,), cap_a, jnp.int32)
    for _ in range(steps):           # static unroll: ceil(log2(capA+1)) steps
        # `active` guards converged lanes: an unguarded extra step past
        # lo == hi would overshoot the true lower bound
        active = lo < hi
        mid = (lo + hi) // 2
        vals = jnp.take(ka, jnp.minimum(mid, cap_a - 1))
        go_right = active & (vals < kb)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
    lo_ref[...] = lo


def probe_lower_bound(
    ka_sorted: jnp.ndarray,   # (capA,) uint32 ascending
    kb: jnp.ndarray,          # (capB,) uint32
    *,
    bb: int = 2048,
    interpret: bool = False,
) -> jnp.ndarray:
    """searchsorted(ka, kb, side='left') as a Pallas kernel."""
    cap_a = ka_sorted.shape[0]
    n = kb.shape[0]
    bb = min(bb, n)
    while n % bb:
        bb //= 2
    # interval [0, cap_a] has cap_a + 1 states: power-of-two cap_a needs
    # bit_length(cap_a) steps — bit_length(cap_a - 1) was one short, and the
    # off-by-one surfaced exactly when a duplicate run filled the window
    steps = max(1, cap_a.bit_length())
    return pl.pallas_call(
        functools.partial(_probe_kernel, cap_a=cap_a, steps=steps),
        grid=(n // bb,),
        in_specs=[
            pl.BlockSpec((cap_a,), lambda i: (0,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(ka_sorted, kb)


def _window_kernel(ka_ref, kb_ref, lo_ref, hit_ref, idx_ref, *, cap_a, dup_cap):
    ka = ka_ref[...]
    kb = kb_ref[...]
    lo = lo_ref[...]
    probe = lo[:, None] + jax.lax.broadcasted_iota(jnp.int32, (kb.shape[0], dup_cap), 1)
    in_range = probe < cap_a
    pc = jnp.minimum(probe, cap_a - 1)
    vals = jnp.take(ka, pc)
    hit_ref[...] = in_range & (vals == kb[:, None])
    idx_ref[...] = pc


def probe_window(
    ka_sorted: jnp.ndarray,
    kb: jnp.ndarray,
    lo: jnp.ndarray,
    *,
    dup_cap: int,
    bb: int = 2048,
    interpret: bool = False,
):
    """Expand each probe's run window: (hit (capB, W) bool, idx (capB, W))."""
    cap_a = ka_sorted.shape[0]
    n = kb.shape[0]
    bb = min(bb, n)
    while n % bb:
        bb //= 2
    return pl.pallas_call(
        functools.partial(_window_kernel, cap_a=cap_a, dup_cap=dup_cap),
        grid=(n // bb,),
        in_specs=[
            pl.BlockSpec((cap_a,), lambda i: (0,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, dup_cap), lambda i: (i, 0)),
            pl.BlockSpec((bb, dup_cap), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, dup_cap), jnp.bool_),
            jax.ShapeDtypeStruct((n, dup_cap), jnp.int32),
        ],
        interpret=interpret,
    )(ka_sorted, kb, lo)
