"""Training launcher: ``python -m repro.extras.train --arch <id> [...]``.

Single-host, any device count; for the full-pod meshes use dryrun.py (this
container has one real device). Wires: config registry → data pipeline →
train step → AdamW → checkpointer → fault-tolerant supervisor.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import optim
from repro.checkpoint import Checkpointer
from repro.configs import get
from repro.configs.base import GNNConfig, LMConfig, RecSysConfig
from repro.data import pipeline as data
from repro.graphstore import generators
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tf
from repro.models.schema import init_params
from repro.runtime import TrainSupervisor
from repro.train import make_train_step


def build(arch: str, *, smoke: bool, batch: int, seq: int, seed: int):
    entry = get(arch)
    cfg = entry.smoke() if smoke else entry.config
    key = jax.random.PRNGKey(seed)
    if isinstance(cfg, LMConfig):
        params = tf.init(cfg, key)
        batch_fn = lambda step: data.lm_batch(cfg, batch, seq, seed=seed, step=step)
    elif isinstance(cfg, GNNConfig):
        params = init_params(gnn_lib.gnn_schema(cfg), key)
        g = generators.rmat(512, 2048, 8, seed=seed)
        batch_fn = lambda step: {
            "graph": data.gnn_full_batch(cfg, g, n_classes=cfg.n_classes, seed=seed)
        }
    elif isinstance(cfg, RecSysConfig):
        params = init_params(recsys_lib.recsys_schema(cfg), key)
        batch_fn = lambda step: data.recsys_batch(cfg, batch, seed=seed, step=step)
    else:
        raise ValueError(arch)
    return cfg, params, batch_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg, params, batch_fn = build(
        args.arch, smoke=args.smoke, batch=args.batch, seq=args.seq, seed=args.seed
    )
    opt_cfg = optim.AdamWConfig(lr=args.lr)
    opt_state = optim.init(opt_cfg, params)
    step_fn_raw = jax.jit(
        make_train_step(cfg, opt_cfg, total_steps=args.steps, microbatches=args.microbatches)
    )

    def step_fn(state, batch, step):
        params, opt_state = state
        params, opt_state, metrics = step_fn_raw(
            params, opt_state, batch, np.int32(step)
        )
        return (params, opt_state), metrics

    ckpt = Checkpointer(args.ckpt_dir)
    if not args.resume:
        for p in sorted(__import__("pathlib").Path(args.ckpt_dir).glob("step_*")):
            __import__("shutil").rmtree(p)
    sup = TrainSupervisor(ckpt, ckpt_every=args.ckpt_every)
    state, history = sup.run(
        state=(params, opt_state),
        step_fn=step_fn,
        batch_fn=batch_fn,
        n_steps=args.steps,
    )
    for h in history[:: max(1, len(history) // 10)]:
        print(
            f"step {h['step']:5d}  loss {h['loss']:.4f}  "
            f"grad_norm {h['grad_norm']:.3f}  {h['dt']*1e3:.0f} ms"
        )
    print(f"final loss: {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
