"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2405.04434 / 2412.19437).

Queries are low-rank compressed (q_lora); keys/values share one latent
c_kv (kv_lora) plus a decoupled shared RoPE key (d_rope). The decode path
uses the *absorbed* formulation: scores and values are computed directly in
latent space, so the KV cache is (kv_lora + d_rope) per token — the reason
long_500k decode is feasible for this arch (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models.layers import apply_rope, attention, rms_norm
from repro.models.schema import ParamDef


def mla_schema(cfg: LMConfig) -> dict:
    m = cfg.mla
    L, D, N = cfg.n_layers, cfg.d_model, cfg.n_heads
    dt = cfg.dtype
    return {
        "wq_a": ParamDef((L, D, m.q_lora_rank), ("layer", "fsdp", "lora"), "lecun", dt),
        "q_norm": ParamDef((L, m.q_lora_rank), ("layer", None), "zeros", "float32"),
        "wq_b": ParamDef(
            (L, m.q_lora_rank, N, m.d_nope + m.d_rope),
            ("layer", "lora", "heads", None),
            "lecun",
            dt,
        ),
        "wkv_a": ParamDef(
            (L, D, m.kv_lora_rank + m.d_rope), ("layer", "fsdp", None), "lecun", dt
        ),
        "kv_norm": ParamDef((L, m.kv_lora_rank), ("layer", None), "zeros", "float32"),
        "wk_b": ParamDef(
            (L, m.kv_lora_rank, N, m.d_nope),
            ("layer", "lora", "heads", None),
            "lecun",
            dt,
        ),
        "wv_b": ParamDef(
            (L, m.kv_lora_rank, N, m.d_v),
            ("layer", "lora", "heads", None),
            "lecun",
            dt,
        ),
        "wo": ParamDef(
            (L, N, m.d_v, D), ("layer", "heads", None, "fsdp"), "lecun", dt
        ),
    }


def _project_q(p, x, cfg: LMConfig, positions):
    m = cfg.mla
    q_lat = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsl,lnh->bsnh", q_lat, p["wq_b"])  # (B,S,N,dn+dr)
    q_nope, q_rope = q[..., : m.d_nope], q[..., m.d_nope :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p, x, cfg: LMConfig, positions):
    m = cfg.mla
    kv = x @ p["wkv_a"]  # (B,S,kv_lora + dr)
    c_kv = rms_norm(kv[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank :][:, :, None, :]  # (B,S,1,dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(
    p: dict,                 # this layer's slice of mla_schema params
    x: jnp.ndarray,          # (B, S, D)
    pos: jnp.ndarray,        # (S,) int32
    cfg: LMConfig,
):
    """Training / prefill path: materialize per-head K (nope‖rope) and V from
    the latent, then run the shared (chunked) attention core."""
    m = cfg.mla
    B, S, _ = x.shape
    positions = jnp.broadcast_to(pos, (B, S))
    q_nope, q_rope = _project_q(p, x, cfg, positions)
    c_kv, k_rope = _project_kv_latent(p, x, cfg, positions)
    k_nope = jnp.einsum("bsl,lnh->bsnh", c_kv, p["wk_b"])
    v = jnp.einsum("bsl,lnh->bsnh", c_kv, p["wv_b"])

    N = cfg.n_heads
    q_eff = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,N,dn+dr)
    k_eff = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, N, m.d_rope))],
        axis=-1,
    )
    scale = 1.0 / np.sqrt(m.d_nope + m.d_rope)
    out = attention(q_eff, k_eff, v, pos, pos, scale=scale)
    return jnp.einsum("bqnh,nhd->bqd", out, p["wo"]), (c_kv, k_rope)


def mla_decode(
    p: dict,
    x: jnp.ndarray,          # (B, 1, D)
    pos: jnp.ndarray,        # () current position (== slot; non-rolling)
    cache_ckv: jnp.ndarray,  # (B, S_cap, kv_lora)
    cache_kr: jnp.ndarray,   # (B, S_cap, d_rope)
    cfg: LMConfig,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Absorbed decode: O(S · (kv_lora + d_rope)) per step."""
    m = cfg.mla
    B = x.shape[0]
    S_cap = cache_ckv.shape[1]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q_nope, q_rope = _project_q(p, x, cfg, positions)      # (B,1,N,·)
    c_kv_new, k_rope_new = _project_kv_latent(p, x, cfg, positions)
    zero = np.int32(0)  # match pos's int32: dus indices must share one type
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, c_kv_new.astype(cache_ckv.dtype), (zero, pos, zero)
    )
    cache_kr = jax.lax.dynamic_update_slice(
        cache_kr, k_rope_new.astype(cache_kr.dtype), (zero, pos, zero)
    )

    # absorb: q_eff[b,n,l] = q_nope · wk_b — scores in latent space
    q_eff = jnp.einsum("bqnh,lnh->bqnl", q_nope, p["wk_b"])  # (B,1,N,kv_lora)
    scale = 1.0 / np.sqrt(m.d_nope + m.d_rope)
    logits = (
        jnp.einsum("bqnl,bsl->bnqs", q_eff.astype(jnp.float32), cache_ckv.astype(jnp.float32))
        + jnp.einsum("bqnh,bsh->bnqs", q_rope.astype(jnp.float32), cache_kr.astype(jnp.float32))
    ) * scale
    valid = (jnp.arange(S_cap) <= pos)[None, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bnqs,bsl->bqnl", probs, cache_ckv.astype(jnp.float32))
    out = jnp.einsum("bqnl,lnh->bqnh", o_lat.astype(x.dtype), p["wv_b"])
    return jnp.einsum("bqnh,nhd->bqd", out, p["wo"]), cache_ckv, cache_kr
