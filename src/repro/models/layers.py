"""Shared transformer layers (pure JAX, schema-based params).

Conventions:
  activations bf16 (configurable), softmax/norm statistics in f32;
  masks are never materialized at (Sq, Skv) scale — they are built per tile
  from positions, and sequences beyond ``_NAIVE_LIMIT`` run through an
  online-softmax chunked attention (the pure-jnp flash-attention: also the
  oracle for the Pallas ``flash_attention`` kernel); KV caches are
  [B, S_cap, N_kv, H].
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import logical

_NAIVE_LIMIT = 2048 * 2048  # Sq*Skv above this → chunked path

# Dry-run cost analysis counts lax.scan/map/while bodies ONCE regardless of
# trip count; under ``unrolled_model()`` every structural loop (layer stacks,
# attention tiles) unrolls to plain Python so the (small-depth) cost probes
# in extras/dryrun.py report exact per-layer FLOPs/bytes/collectives.
_UNROLL = contextvars.ContextVar("unroll_model", default=False)


@contextlib.contextmanager
def unrolled_model():
    tok = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


def maybe_scan(body, carry, xs):
    """lax.scan, or an unrolled Python loop under ``unrolled_model()``."""
    if not _UNROLL.get():
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *z: jnp.stack(z), *ys)
    else:
        stacked = None
    return carry, stacked


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * (1.0 + scale.astype(dt))


# ------------------------------------------------------------------- RoPE
def rope_freqs(d: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d, 2, dtype=np.float64) / d))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, N, H); positions: (..., S). Llama convention (half split)."""
    H = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(H, theta), dtype=jnp.float32)  # (H/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, H/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention
def _tile_mask(pos_q, pos_k, window):
    """(.., Sq, Skv) bool from positions; pos_k < 0 marks invalid slots."""
    ok = (pos_k[..., None, :] <= pos_q[..., :, None]) & (pos_k[..., None, :] >= 0)
    if window is not None:
        ok &= pos_k[..., None, :] > pos_q[..., :, None] - window
    return ok


def _logits_tile(qg, k, scale, softcap):
    # qg: (B, Sq, Nkv, G, H); k: (B, Skv, Nkv, H) → (B, Nkv, G, Sq, Skv) f32
    logits = jnp.einsum(
        "bqkgh,bskh->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    )
    logits *= scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def attention(
    q: jnp.ndarray,       # (B, Sq, Nq, H)
    k: jnp.ndarray,       # (B, Skv, Nkv, H)
    v: jnp.ndarray,       # (B, Skv, Nkv, Hv)
    pos_q: jnp.ndarray,   # (Sq,) int32 query positions
    pos_k: jnp.ndarray,   # (Skv,) int32 key positions (-1 = invalid slot)
    *,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    q_chunk: int = 1024,
    kv_chunk: int = 2048,
) -> jnp.ndarray:
    """Grouped-query attention, causal w/ optional sliding window.
    Dispatches to an online-softmax chunked path for long sequences."""
    B, Sq, Nq, H = q.shape
    Skv, Nkv = k.shape[1], k.shape[2]
    G = Nq // Nkv
    scale = scale if scale is not None else 1.0 / np.sqrt(H)
    qg = q.reshape(B, Sq, Nkv, G, H)

    if Sq * Skv <= _NAIVE_LIMIT:
        logits = _logits_tile(qg, k, scale, softcap)
        mask = _tile_mask(pos_q, pos_k, window)  # (Sq, Skv)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
        return out.reshape(B, Sq, Nq, v.shape[-1]).astype(q.dtype)

    # ---------------- chunked (flash-style) path ---------------------------
    Hv = v.shape[-1]
    qc = min(q_chunk, Sq)
    while Sq % qc:
        qc //= 2
    kc = min(kv_chunk, Skv)
    while Skv % kc:
        kc //= 2
    nq, nk = Sq // qc, Skv // kc

    q_t = qg.reshape(B, nq, qc, Nkv, G, H).transpose(1, 0, 2, 3, 4, 5)
    pos_q_t = pos_q.reshape(nq, qc)
    k_t = k.reshape(B, nk, kc, Nkv, H).transpose(1, 0, 2, 3, 4)
    v_t = v.reshape(B, nk, kc, Nkv, Hv).transpose(1, 0, 2, 3, 4)
    pos_k_t = pos_k.reshape(nk, kc)

    def q_block(args):
        qb, pq = args  # (B, qc, Nkv, G, H), (qc,)

        def kv_step(carry, xs):
            m, l, acc = carry
            kb, vb, pk = xs
            logits = _logits_tile(qb, kb, scale, softcap)  # (B,Nkv,G,qc,kc)
            mask = _tile_mask(pq, pk, window)
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p, vb.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, Nkv, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Nkv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Nkv, G, qc, Hv), jnp.float32)
        if _UNROLL.get():
            carry = (m0, l0, a0)
            for j in range(nk):
                carry, _ = kv_step(carry, (k_t[j], v_t[j], pos_k_t[j]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), (k_t, v_t, pos_k_t)
            )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)  # (B, qc, Nkv, G, Hv)

    if _UNROLL.get():
        out = jnp.stack([q_block((q_t[i], pos_q_t[i])) for i in range(nq)])
    else:
        out = jax.lax.map(q_block, (q_t, pos_q_t))  # (nq, B, qc, Nkv, G, Hv)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Nq, Hv)
    return out.astype(q.dtype)


def blocked_decode_attention(
    q: jnp.ndarray,       # (B, 1, Nq, H)
    k_cache: jnp.ndarray,  # (B, S, Nkv, H) — S sharded over the mesh
    v_cache: jnp.ndarray,
    pos_q: jnp.ndarray,   # (1,)
    pos_k: jnp.ndarray,   # (S,)
    n_blocks: int,
    *,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Flash-decoding (split-KV) for one-token decode: per-block softmax
    stats (m, l, acc) computed block-locally, combined across blocks — the
    cross-shard traffic is O(B·N·(Hv+2)·n_blocks) stats instead of the whole
    KV cache (§Perf iteration 2)."""
    B, S, Nkv, H = k_cache.shape
    Nq = q.shape[2]
    G = Nq // Nkv
    Hv = v_cache.shape[-1]
    Sb = S // n_blocks
    scale = scale if scale is not None else 1.0 / np.sqrt(H)

    kb = logical(
        k_cache.reshape(B, n_blocks, Sb, Nkv, H),
        "batch", "kv_block", None, "kv_heads", None,
    )
    vb = logical(
        v_cache.reshape(B, n_blocks, Sb, Nkv, Hv),
        "batch", "kv_block", None, "kv_heads", None,
    )
    pos_kb = pos_k.reshape(n_blocks, Sb)
    # replicate q across the model axis (a few MB) so every shard scores its
    # own KV blocks locally — resharding activations, never weights
    qg = logical(q.reshape(B, Nkv, G, H), "batch", None, None, None)

    logits = jnp.einsum(
        "bkgh,bnskh->bnkgs", qg.astype(jnp.float32), kb.astype(jnp.float32)
    ) * scale  # (B, nb, Nkv, G, Sb)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    ok = (pos_kb <= pos_q[0]) & (pos_kb >= 0)
    if window is not None:
        ok &= pos_kb > pos_q[0] - window
    logits = jnp.where(ok[None, :, None, None, :], logits, -1e30)
    logits = logical(logits, "batch", "kv_block", "kv_heads", None, None)

    m_b = jnp.max(logits, axis=-1)                      # (B, nb, Nkv, G)
    p = jnp.exp(logits - m_b[..., None])
    l_b = jnp.sum(p, axis=-1)
    acc_b = jnp.einsum("bnkgs,bnskh->bnkgh", p, vb.astype(jnp.float32))
    # combine across blocks (the only cross-shard reduction)
    m = jnp.max(m_b, axis=1)                            # (B, Nkv, G)
    corr = jnp.exp(m_b - m[:, None])
    l = jnp.sum(l_b * corr, axis=1)
    acc = jnp.sum(acc_b * corr[..., None], axis=1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, 1, Nq, Hv).astype(q.dtype)


def causal_mask(q_len: int, kv_len: int, *, window: int | None = None) -> jnp.ndarray:
    """Additive small-scale mask (tests / reference only)."""
    qi = jnp.arange(q_len)[:, None] + (kv_len - q_len)
    kj = jnp.arange(kv_len)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= kj > qi - window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


# ------------------------------------------------------------------- MLPs
def glu_mlp(x: jnp.ndarray, wi_gate, wi_up, wo, act: str) -> jnp.ndarray:
    g = x @ wi_gate
    u = x @ wi_up
    if act == "swiglu":
        h = jax.nn.silu(g) * u
    elif act == "geglu":
        h = jax.nn.gelu(g, approximate=True) * u
    else:
        raise ValueError(act)
    h = logical(h, "batch", "seq", "mlp")
    return h @ wo


def dense_mlp(x: jnp.ndarray, wi, wo, act: str = "gelu") -> jnp.ndarray:
    h = jax.nn.gelu(x @ wi) if act == "gelu" else jax.nn.relu(x @ wi)
    return h @ wo


def mlp_stack(x: jnp.ndarray, params: dict, n: int, act=jax.nn.relu) -> jnp.ndarray:
    """Small n-layer MLP used by GNN/recsys models: params w0,b0,..wk,bk."""
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = act(x)
    return x
