"""Mixture-of-Experts FFN with static-shape sort-based dispatch.

Routing variants:
  * softmax top-k, renormalized over the chosen experts  (Mixtral)
  * sigmoid scores + aux-loss-free bias balancing + group-limited top-k,
    normalized over chosen                                (DeepSeek-V3)

Dispatch: flatten (token, k) assignments, sort by expert id, pack each
expert's tokens into a capacity-bounded (E, C, D) buffer (dropped tokens fall
back to the residual path — standard capacity-factor semantics), run the
expert GEMMs batched over E, scatter-add back with combine weights. All
shapes static; the E axis shards over the `model` mesh axis (expert
parallelism) and XLA inserts the dispatch all-to-alls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.launch.sharding import logical
from repro.models.schema import ParamDef


def moe_schema(cfg: MoEConfig, n_layers: int, d_model: int, dtype: str) -> dict:
    e, f = cfg.n_experts, cfg.d_ff_expert
    L = n_layers
    sch = {
        "router": ParamDef((L, d_model, e), ("layer", "embed", "expert"), "lecun", "float32"),
        "wi_gate": ParamDef((L, e, d_model, f), ("layer", "expert", "fsdp", "expert_mlp"), "lecun", dtype),
        "wi_up": ParamDef((L, e, d_model, f), ("layer", "expert", "fsdp", "expert_mlp"), "lecun", dtype),
        "wo": ParamDef((L, e, f, d_model), ("layer", "expert", "expert_mlp", "fsdp"), "lecun", dtype),
    }
    if cfg.router_bias_balancing:
        sch["router_bias"] = ParamDef((L, e), ("layer", "expert"), "zeros", "float32")
    if cfg.n_shared:
        fs = f * cfg.n_shared
        sch["shared_wi_gate"] = ParamDef((L, d_model, fs), ("layer", "fsdp", "mlp"), "lecun", dtype)
        sch["shared_wi_up"] = ParamDef((L, d_model, fs), ("layer", "fsdp", "mlp"), "lecun", dtype)
        sch["shared_wo"] = ParamDef((L, fs, d_model), ("layer", "mlp", "fsdp"), "lecun", dtype)
    return sch


def route(
    x: jnp.ndarray,              # (T, D)
    w_router: jnp.ndarray,       # (D, E)
    bias: jnp.ndarray | None,    # (E,) balancing bias (DSv3) or None
    cfg: MoEConfig,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (expert_idx (T, K), combine_weights (T, K), aux_loss ())."""
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)  # (T, E)
    if cfg.router == "softmax":
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / jnp.sum(w, axis=-1, keepdims=True)
    elif cfg.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + (bias[None, :] if bias is not None else 0.0)
        if cfg.n_groups > 1:
            T = x.shape[0]
            g = sel.reshape(T, cfg.n_groups, -1)
            # group score = sum of top-2 affinities in the group (DSv3)
            g2 = jnp.sum(jax.lax.top_k(g, 2)[0], axis=-1)       # (T, G)
            _, gidx = jax.lax.top_k(g2, cfg.top_groups)
            gmask = jnp.zeros_like(g2).at[
                jnp.arange(T)[:, None], gidx
            ].set(1.0)
            sel = jnp.where(
                jnp.repeat(gmask, sel.shape[-1] // cfg.n_groups, axis=-1) > 0,
                sel,
                -jnp.inf,
            )
        _, idx = jax.lax.top_k(sel, cfg.top_k)
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-20)
    else:
        raise ValueError(cfg.router)
    aux = jnp.float32(0.0)
    if cfg.aux_loss_weight > 0:
        # Switch-style load-balance loss
        E = logits.shape[-1]
        probs = jax.nn.softmax(logits, axis=-1)
        hot = jnp.zeros_like(probs).at[
            jnp.arange(x.shape[0])[:, None], idx
        ].add(1.0)
        frac = jnp.mean(hot, axis=0)
        imp = jnp.mean(probs, axis=0)
        aux = cfg.aux_loss_weight * E * jnp.sum(frac * imp)
    return idx.astype(jnp.int32), w.astype(jnp.float32), aux


def moe_ffn(
    x: jnp.ndarray,              # (T, D)
    layer_params: dict,          # this layer's slice of moe_schema params
    cfg: MoEConfig,
    act: str = "swiglu",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (T, D), aux_loss)."""
    if cfg.dispatch_groups > 1 and x.shape[0] % cfg.dispatch_groups == 0:
        return _moe_ffn_grouped(x, layer_params, cfg, act)
    return _moe_ffn_global(x, layer_params, cfg, act)


def _moe_ffn_global(x, layer_params, cfg, act):
    """Paper-faithful baseline: one global sort-dispatch over all tokens.
    Under SPMD this all-gathers activations for the permutation gather —
    the dominant collective term in the MoE dry-runs (EXPERIMENTS.md §Perf
    iteration 1 replaces it with the grouped dispatch below)."""
    T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    # capacity-factor bound, with a small-batch no-drop floor (decode batches
    # must never drop tokens: C >= T guarantees it and is cheap when T <= 64)
    C = max(1, int(np.ceil(T * K / E * cfg.capacity_factor)), min(T, 64))
    bias = layer_params.get("router_bias")
    idx, w, aux = route(x, layer_params["router"], bias, cfg)

    # ---- sort-based dispatch -------------------------------------------
    flat_e = idx.reshape(-1)                       # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e)                    # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within expert = position - first position of that expert
    pos = jnp.arange(T * K, dtype=jnp.int32)
    first = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype)).astype(jnp.int32)
    rank = pos - first[se]
    keep = rank < C
    buf_e = jnp.where(keep, se, E)
    buf_r = jnp.where(keep, rank, C)

    xb = jnp.zeros((E + 1, C + 1, D), x.dtype)
    xb = xb.at[buf_e, buf_r].set(x[st], mode="drop")
    xb = xb[:E, :C]
    xb = logical(xb, "expert", "expert_capacity", None)

    # ---- expert GEMMs ----------------------------------------------------
    wi_g, wi_u, wo = (
        layer_params["wi_gate"],
        layer_params["wi_up"],
        layer_params["wo"],
    )
    g = jnp.einsum("ecd,edf->ecf", xb, wi_g)
    u = jnp.einsum("ecd,edf->ecf", xb, wi_u)
    h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
    h = logical(h, "expert", "expert_capacity", "expert_mlp")
    yb = jnp.einsum("ecf,efd->ecd", h, wo)
    yb = logical(yb, "expert", "expert_capacity", None)

    # ---- combine ---------------------------------------------------------
    contrib = yb[buf_e.clip(0, E - 1), buf_r.clip(0, C - 1)]  # (T*K, D)
    contrib = jnp.where(keep[:, None], contrib, 0.0)
    out = jnp.zeros((T, D), x.dtype).at[st].add(
        contrib * sw[:, None].astype(x.dtype)
    )

    # ---- shared experts (always-on, DSv3) --------------------------------
    if cfg.n_shared:
        g = x @ layer_params["shared_wi_gate"]
        u = x @ layer_params["shared_wi_up"]
        h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
        out = out + h @ layer_params["shared_wo"]
    return out, aux


def _moe_ffn_grouped(x, layer_params, cfg, act):
    """§Perf optimization: DP-group-local dispatch. Tokens are reshaped
    (G, T/G, D) with G = the data-parallel shard count; routing, sorting and
    the dispatch gather/scatter are *batched per group* so they never cross
    shards — only the (G, E, C, D) → expert-sharded buffer boundary moves
    bytes (an all-to-all), plus the FSDP weight all-gather that ZeRO-3
    already pays. Numerics are identical to the global dispatch up to
    capacity dropping (per-group capacity vs global capacity)."""
    T, D = x.shape
    G = cfg.dispatch_groups
    E, K = cfg.n_experts, cfg.top_k
    Tg = T // G
    C = max(1, int(np.ceil(Tg * K / E * cfg.capacity_factor)), min(Tg, 64))
    bias = layer_params.get("router_bias")

    xg = x.reshape(G, Tg, D)
    xg = logical(xg, "expert_group", None, None)
    idx, w, aux = jax.vmap(
        lambda xb: route(xb, layer_params["router"], bias, cfg)
    )(xg)  # (G, Tg, K)
    aux = jnp.mean(aux)

    def dispatch(xb, idx_b, w_b):
        flat_e = idx_b.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K)
        flat_w = w_b.reshape(-1)
        order = jnp.argsort(flat_e)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        pos = jnp.arange(Tg * K, dtype=jnp.int32)
        first = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype)).astype(jnp.int32)
        rank = pos - first[se]
        keep = rank < C
        buf_e = jnp.where(keep, se, E)
        buf_r = jnp.where(keep, rank, C)
        xb_buf = jnp.zeros((E + 1, C + 1, D), xb.dtype)
        xb_buf = xb_buf.at[buf_e, buf_r].set(xb[st], mode="drop")[:E, :C]
        return xb_buf, (buf_e, buf_r, st, sw, keep)

    xbuf, meta = jax.vmap(dispatch)(xg, idx, w)  # (G, E, C, D)
    xbuf = logical(xbuf, "expert_group", "expert", None, None)

    wi_g, wi_u, wo = (
        layer_params["wi_gate"],
        layer_params["wi_up"],
        layer_params["wo"],
    )
    g_ = jnp.einsum("gecd,edf->gecf", xbuf, wi_g)
    u_ = jnp.einsum("gecd,edf->gecf", xbuf, wi_u)
    h = (jax.nn.silu(g_) if act == "swiglu" else jax.nn.gelu(g_)) * u_
    h = logical(h, "expert_group", "expert", None, "expert_mlp")
    ybuf = jnp.einsum("gecf,efd->gecd", h, wo)
    ybuf = logical(ybuf, "expert_group", "expert", None, None)

    def combine(yb, m):
        buf_e, buf_r, st, sw, keep = m
        contrib = yb[buf_e.clip(0, E - 1), buf_r.clip(0, C - 1)]
        contrib = jnp.where(keep[:, None], contrib, 0.0)
        return jnp.zeros((Tg, D), x.dtype).at[st].add(
            contrib * sw[:, None].astype(x.dtype)
        )

    out = jax.vmap(combine)(ybuf, meta).reshape(T, D)

    if cfg.n_shared:
        gsh = x @ layer_params["shared_wi_gate"]
        ush = x @ layer_params["shared_wi_up"]
        hsh = (jax.nn.silu(gsh) if act == "swiglu" else jax.nn.gelu(gsh)) * ush
        out = out + hsh @ layer_params["shared_wo"]
    return out, aux


def router_bias_update(
    bias: jnp.ndarray, idx: jnp.ndarray, n_experts: int, gamma: float = 1e-3
) -> jnp.ndarray:
    """DeepSeek-V3 aux-loss-free balancing: nudge under-loaded experts'
    selection bias up, over-loaded down (applied outside the gradient)."""
    load = jnp.zeros((n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    mean = jnp.mean(load)
    return bias + gamma * jnp.sign(mean - load)
