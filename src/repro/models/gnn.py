"""GNN model zoo: GatedGCN, EGNN, GIN-ε, MeshGraphNet.

Message passing is implemented exactly as the brief requires for JAX:
``jax.ops.segment_sum`` (+max) over an edge-index → node scatter. The graphs
come from ``repro.graphstore`` (same partitioned substrate as the matching
engine); padded edges carry ``edge_mask``.

Batch layout (static shapes):
  node_feat (N, d_in) · node_pos (N, 3, EGNN) · edge_src/dst (E,) int32
  edge_feat (E, d_e) · node_mask (N,) · edge_mask (E,) · graph_id (N,)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.launch.sharding import logical
from repro.models.layers import maybe_scan
from repro.models.schema import ParamDef, init_params


class GraphBatch(NamedTuple):
    node_feat: jnp.ndarray
    edge_src: jnp.ndarray
    edge_dst: jnp.ndarray
    node_mask: jnp.ndarray
    edge_mask: jnp.ndarray
    edge_feat: jnp.ndarray | None = None
    node_pos: jnp.ndarray | None = None
    graph_id: jnp.ndarray | None = None
    n_graphs: int = 1
    labels: jnp.ndarray | None = None
    label_mask: jnp.ndarray | None = None


def _mlp_def(d_in: int, d_hidden: int, d_out: int, n: int, prefix_dims=None):
    """Schema for an n-layer MLP, optionally stacked over leading dims."""
    pd = tuple(prefix_dims or ())
    pax = ("layer",) * len(pd)
    sch = {}
    dims = [d_in] + [d_hidden] * (n - 1) + [d_out]
    for i in range(n):
        sch[f"w{i}"] = ParamDef(pd + (dims[i], dims[i + 1]), pax + (None, "hidden"), "he")
        sch[f"b{i}"] = ParamDef(pd + (dims[i + 1],), pax + ("hidden",), "zeros")
    return sch


def _mlp(params, x, n, act=jax.nn.relu):
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = act(x)
    return x


def _seg_sum(data, idx, n):
    return jax.ops.segment_sum(data, idx, num_segments=n)


# ------------------------------------------------------------------ schema
def gnn_schema(cfg: GNNConfig) -> dict:
    L, dh = cfg.n_layers, cfg.d_hidden
    sch: dict = {
        "enc_node": _mlp_def(cfg.d_in, dh, dh, 2),
        "head": _mlp_def(dh, dh, cfg.n_classes, 2),
    }
    if cfg.kind == "gin":
        sch["layers"] = {
            **_mlp_def(dh, dh, dh, 2, prefix_dims=(L,)),
        }
        if cfg.learnable_eps:
            sch["eps"] = ParamDef((L,), ("layer",), "zeros")
    elif cfg.kind == "gatedgcn":
        sch["enc_edge"] = _mlp_def(max(cfg.d_edge, 1), dh, dh, 1)
        sch["layers"] = {
            "A": ParamDef((L, dh, dh), ("layer", None, "hidden"), "he"),
            "B": ParamDef((L, dh, dh), ("layer", None, "hidden"), "he"),
            "C": ParamDef((L, dh, dh), ("layer", None, "hidden"), "he"),
            "U": ParamDef((L, dh, dh), ("layer", None, "hidden"), "he"),
            "V": ParamDef((L, dh, dh), ("layer", None, "hidden"), "he"),
            "norm_h": ParamDef((L, dh), ("layer", None), "zeros"),
            "norm_e": ParamDef((L, dh), ("layer", None), "zeros"),
        }
    elif cfg.kind == "egnn":
        sch["layers"] = {
            "phi_e": _mlp_def(2 * dh + 1 + (cfg.d_edge or 0), dh, dh, 2),
            "phi_x": _mlp_def(dh, dh, 1, 2),
            "phi_h": _mlp_def(2 * dh, dh, dh, 2),
        }
        # EGNN layers stacked:
        sch["layers"] = {
            k: {
                kk: ParamDef((L,) + d.shape, ("layer",) + d.axes, d.init)
                for kk, d in v.items()
            }
            for k, v in sch["layers"].items()
        }
    elif cfg.kind == "meshgraphnet":
        sch["enc_edge"] = _mlp_def(max(cfg.d_edge, 1), dh, dh, cfg.mlp_layers)
        mk = lambda din: {
            kk: ParamDef((L,) + d.shape, ("layer",) + d.axes, d.init)
            for kk, d in _mlp_def(din, dh, dh, cfg.mlp_layers).items()
        }
        sch["layers"] = {
            "edge_mlp": mk(3 * dh),
            "node_mlp": mk(2 * dh),
        }
    else:
        raise ValueError(cfg.kind)
    return sch


# ---------------------------------------------------------------- forward
def forward(cfg: GNNConfig, params: dict, g: GraphBatch) -> jnp.ndarray:
    N = g.node_feat.shape[0]
    dh = cfg.d_hidden
    em = g.edge_mask[:, None].astype(g.node_feat.dtype)
    h = _mlp(params["enc_node"], g.node_feat, 2)
    h = logical(h, "nodes", "hidden")

    if cfg.kind == "gin":
        def body(h, pl):
            agg = _seg_sum(h[g.edge_src] * em, g.edge_dst, N)
            eps = pl.get("eps", jnp.zeros(()))
            out = _mlp(pl, (1.0 + eps) * h + agg, 2)
            return jax.nn.relu(out), None

        stack = dict(params["layers"])
        if cfg.learnable_eps:
            stack["eps"] = params["eps"]
        h, _ = maybe_scan(body, h, stack)

    elif cfg.kind == "gatedgcn":
        ef = g.edge_feat
        if ef is None:
            ef = jnp.ones((g.edge_src.shape[0], 1), h.dtype)
        e = _mlp(params["enc_edge"], ef, 1)

        def body(carry, pl):
            h, e = carry
            hs, hd = h[g.edge_src], h[g.edge_dst]
            e_new = hd @ pl["A"] + hs @ pl["B"] + e @ pl["C"]
            e = _ln(e + jax.nn.relu(e_new), pl["norm_e"])
            gate = jax.nn.sigmoid(e) * em
            denom = _seg_sum(gate, g.edge_dst, N) + 1e-6
            msg = _seg_sum(gate * (hs @ pl["V"]), g.edge_dst, N) / denom
            h = _ln(h + jax.nn.relu(h @ pl["U"] + msg), pl["norm_h"])
            return (h, e), None

        (h, _), _ = maybe_scan(body, (h, e), params["layers"])

    elif cfg.kind == "egnn":
        x = g.node_pos
        assert x is not None, "EGNN requires node_pos"

        def body(carry, pl):
            h, x = carry
            xs, xd = x[g.edge_src], x[g.edge_dst]
            d2 = jnp.sum((xd - xs) ** 2, axis=-1, keepdims=True)
            inp = [h[g.edge_dst], h[g.edge_src], d2]
            if g.edge_feat is not None and cfg.d_edge:
                inp.append(g.edge_feat)
            m = _mlp(pl["phi_e"], jnp.concatenate(inp, -1), 2)
            m = jax.nn.silu(m) * em
            w = _mlp(pl["phi_x"], m, 2)                       # (E, 1)
            deg = _seg_sum(em, g.edge_dst, N) + 1.0
            x = x + _seg_sum((xd - xs) * w * em, g.edge_dst, N) / deg
            agg = _seg_sum(m, g.edge_dst, N)
            h = h + _mlp(pl["phi_h"], jnp.concatenate([h, agg], -1), 2)
            return (h, x), None

        (h, _), _ = maybe_scan(body, (h, x), params["layers"])

    elif cfg.kind == "meshgraphnet":
        ef = g.edge_feat
        if ef is None:
            ef = jnp.ones((g.edge_src.shape[0], 1), h.dtype)
        e = _mlp(params["enc_edge"], ef, cfg.mlp_layers)

        def body(carry, pl):
            h, e = carry
            e = e + _mlp(
                pl["edge_mlp"],
                jnp.concatenate([e, h[g.edge_src], h[g.edge_dst]], -1),
                cfg.mlp_layers,
            )
            agg = _seg_sum(e * em, g.edge_dst, N)
            h = h + _mlp(pl["node_mlp"], jnp.concatenate([h, agg], -1), cfg.mlp_layers)
            return (h, e), None

        (h, _), _ = maybe_scan(body, (h, e), params["layers"])

    h = logical(h, "nodes", "hidden")
    if cfg.task == "graph":
        # n_graphs must be static under jit: derive from the labels shape
        G = g.labels.shape[0] if g.labels is not None else int(g.n_graphs)
        gid = g.graph_id if g.graph_id is not None else jnp.zeros((N,), jnp.int32)
        pooled = _seg_sum(h * g.node_mask[:, None], gid, G)
        cnt = _seg_sum(g.node_mask.astype(h.dtype), gid, G)[:, None]
        return _mlp(params["head"], pooled / jnp.maximum(cnt, 1.0), 2)
    return _mlp(params["head"], h, 2)


def _ln(x, scale):
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.var(x, axis=-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + 1e-5) * (1.0 + scale)


def loss_fn(cfg: GNNConfig, params: dict, g: GraphBatch) -> jnp.ndarray:
    out = forward(cfg, params, g)
    float_labels = g.labels is not None and jnp.issubdtype(
        g.labels.dtype, jnp.floating
    )
    if cfg.task == "graph":
        if float_labels:  # graph-level regression (MeshGraphNet × molecule)
            return jnp.mean((out[..., 0] - g.labels.astype(out.dtype)) ** 2)
        lp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, g.labels[:, None], axis=-1)[:, 0]
        return jnp.mean(nll)
    if cfg.task == "regression" or float_labels:
        tgt = g.labels.astype(out.dtype)
        mask = (g.label_mask if g.label_mask is not None else g.node_mask).astype(out.dtype)
        return jnp.sum(((out[..., 0] - tgt) ** 2) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    lp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, g.labels[:, None], axis=-1)[:, 0]
    mask = (g.label_mask if g.label_mask is not None else g.node_mask).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def init(cfg: GNNConfig, key: jax.Array) -> dict:
    return init_params(gnn_schema(cfg), key)
