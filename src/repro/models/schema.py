"""Single-source-of-truth parameter schemas.

A model declares its parameters once as a nested dict of ``ParamDef``s
(shape + logical axes + initializer). From that one schema we derive:
  * ``init_params``  — materialized pytree (PRNG-split per leaf),
  * ``abstract_params`` — ShapeDtypeStructs for .lower() dry-runs,
  * ``param_specs`` — NamedShardings / PartitionSpecs via the active rules.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import spec_for


Initializer = Callable[[jax.Array, tuple[int, ...], jnp.dtype], jax.Array]


def _he(key, shape, dtype):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    return (jax.random.normal(key, shape) * np.sqrt(2.0 / fan_in)).astype(dtype)


def _lecun(key, shape, dtype):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    return (jax.random.normal(key, shape) * np.sqrt(1.0 / fan_in)).astype(dtype)


def _embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


def _zeros(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def _ones(key, shape, dtype):
    return jnp.ones(shape, dtype)


INITS: dict[str, Initializer] = {
    "he": _he,
    "lecun": _lecun,
    "embed": _embed_init,
    "zeros": _zeros,
    "ones": _ones,
}


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical axes, len == len(shape)
    init: str = "lecun"
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Schema = dict  # nested dict[str, ParamDef | Schema]


def _flatten(schema: Schema, prefix=()):
    for k, v in schema.items():
        if isinstance(v, ParamDef):
            yield prefix + (k,), v
        else:
            yield from _flatten(v, prefix + (k,))


def init_params(schema: Schema, key: jax.Array):
    flat = list(_flatten(schema))
    keys = jax.random.split(key, max(len(flat), 1))
    out: dict = {}
    for (path, d), k in zip(flat, keys):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = INITS[d.init](k, d.shape, jnp.dtype(d.dtype))
    return out


def abstract_params(schema: Schema):
    out: dict = {}
    for path, d in _flatten(schema):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype))
    return out


def param_pspecs(schema: Schema):
    """PartitionSpecs under the currently-active axis rules."""
    out: dict = {}
    for path, d in _flatten(schema):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = spec_for(d.axes)
    return out


def count_params(schema: Schema) -> int:
    return sum(int(np.prod(d.shape)) for _, d in _flatten(schema))
