"""Decoder-only LM supporting the assigned families:

  qwen2-72b / qwen1.5-110b  — GQA + QKV bias, SwiGLU
  gemma-2b                  — MQA (kv=1), GeGLU, head_dim 256, scaled embed
  mixtral-8x22b             — GQA + sliding-window attention, MoE 8e top-2
  deepseek-v3-671b          — MLA, 1 shared + 256 routed top-8 (sigmoid,
                              aux-free bias), first-3-dense, MTP head

Layers run under ``lax.scan`` over stacked parameters (compile time stays
flat in depth — essential for 80-layer dry-runs), with optional per-layer
remat. Decode uses in-place KV caches: rolling-window slots available for
SWA archs, latent (c_kv, k_rope) for MLA.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.launch.sharding import logical
from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models.layers import apply_rope, attention, glu_mlp, maybe_scan, rms_norm
from repro.models.schema import ParamDef, init_params


# ------------------------------------------------------------------ schema
def lm_schema(cfg: LMConfig) -> dict:
    L, D, N, Nkv, H, F, V = (
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.d_head,
        cfg.d_ff,
        cfg.vocab_size,
    )
    dt = cfg.dtype
    sch: dict = {
        "embed": ParamDef((V, D), ("vocab", "embed"), "embed", dt),
        "norm_attn": ParamDef((L, D), ("layer", None), "zeros", "float32"),
        "norm_ffn": ParamDef((L, D), ("layer", None), "zeros", "float32"),
        "final_norm": ParamDef((D,), (None,), "zeros", "float32"),
    }
    if not cfg.tie_embeddings:
        sch["lm_head"] = ParamDef((D, V), ("embed", "vocab"), "lecun", dt)
    if cfg.mla is not None:
        sch["mla"] = mla_lib.mla_schema(cfg)
    else:
        attn = {
            "wq": ParamDef((L, D, N, H), ("layer", "fsdp", "heads", None), "lecun", dt),
            "wk": ParamDef((L, D, Nkv, H), ("layer", "fsdp", "kv_heads", None), "lecun", dt),
            "wv": ParamDef((L, D, Nkv, H), ("layer", "fsdp", "kv_heads", None), "lecun", dt),
            "wo": ParamDef((L, N, H, D), ("layer", "heads", None, "fsdp"), "lecun", dt),
        }
        if cfg.qkv_bias:
            attn["bq"] = ParamDef((L, N, H), ("layer", "heads", None), "zeros", dt)
            attn["bk"] = ParamDef((L, Nkv, H), ("layer", "kv_heads", None), "zeros", dt)
            attn["bv"] = ParamDef((L, Nkv, H), ("layer", "kv_heads", None), "zeros", dt)
        sch["attn"] = attn
    if cfg.moe is not None:
        k = cfg.moe.first_k_dense
        if k:
            fd = cfg.moe.d_ff_dense or F
            sch["ffn_dense"] = {
                "wi_gate": ParamDef((k, D, fd), ("layer", "fsdp", "mlp"), "lecun", dt),
                "wi_up": ParamDef((k, D, fd), ("layer", "fsdp", "mlp"), "lecun", dt),
                "wo": ParamDef((k, fd, D), ("layer", "mlp", "fsdp"), "lecun", dt),
            }
        sch["moe"] = moe_lib.moe_schema(cfg.moe, L - k, D, dt)
    else:
        sch["ffn_dense"] = {
            "wi_gate": ParamDef((L, D, F), ("layer", "fsdp", "mlp"), "lecun", dt),
            "wi_up": ParamDef((L, D, F), ("layer", "fsdp", "mlp"), "lecun", dt),
            "wo": ParamDef((L, F, D), ("layer", "mlp", "fsdp"), "lecun", dt),
        }
    if cfg.mtp_depth > 0:
        sch["mtp"] = {
            "proj": ParamDef((2 * D, D), ("fsdp", "embed"), "lecun", dt),
            "norm": ParamDef((D,), (None,), "zeros", "float32"),
        }
    return sch


# ----------------------------------------------------------------- helpers
def _gqa_qkv(pl: dict, x: jnp.ndarray, positions, cfg: LMConfig):
    q = jnp.einsum("bsd,dnh->bsnh", x, pl["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, pl["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, pl["wv"])
    if cfg.qkv_bias:
        q = q + pl["bq"]
        k = k + pl["bk"]
        v = v + pl["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(pl_ffn, is_moe: bool, x2: jnp.ndarray, cfg: LMConfig):
    B, S, D = x2.shape
    if is_moe:
        out, aux = moe_lib.moe_ffn(x2.reshape(B * S, D), pl_ffn, cfg.moe, cfg.act)
        return out.reshape(B, S, D), aux
    return (
        glu_mlp(x2, pl_ffn["wi_gate"], pl_ffn["wi_up"], pl_ffn["wo"], cfg.act),
        jnp.float32(0.0),
    )


def _layer_stacks(cfg: LMConfig, params: dict):
    """Split stacked params into (is_moe, stack) groups: the dense prefix and
    the MoE suffix (all-dense models have one group)."""
    k = cfg.moe.first_k_dense if cfg.moe is not None else cfg.n_layers
    attn_key = "mla" if cfg.mla is not None else "attn"
    attn = params[attn_key]
    take = lambda tree, lo, hi: jax.tree.map(lambda a: a[lo:hi], tree)
    stacks = []
    if k > 0:
        stacks.append(
            (
                False,
                {
                    "attn": take(attn, 0, k),
                    "ffn": take(params["ffn_dense"], 0, k),
                    "norm_attn": params["norm_attn"][:k],
                    "norm_ffn": params["norm_ffn"][:k],
                },
            )
        )
    if cfg.moe is not None and cfg.n_layers - k > 0:
        L = cfg.n_layers
        stacks.append(
            (
                True,
                {
                    "attn": take(attn, k, L),
                    "ffn": params["moe"],
                    "norm_attn": params["norm_attn"][k:],
                    "norm_ffn": params["norm_ffn"][k:],
                },
            )
        )
    return stacks


# ---------------------------------------------------------------- forward
def forward(
    cfg: LMConfig,
    params: dict,
    tokens: jnp.ndarray,       # (B, S) int32
    *,
    collect_cache: bool = False,
):
    """Full-sequence forward (train / prefill). Returns (logits, aux_loss,
    caches or None); caches = list per layer-stack of stacked KV arrays."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    x = logical(x, "batch", "seq", "embed")
    pos = jnp.arange(S, dtype=jnp.int32)
    positions = jnp.broadcast_to(pos, (B, S))

    caches = []
    aux_total = jnp.float32(0.0)

    def make_body(is_moe: bool):
        def body(carry, pl):
            x, aux = carry
            h = rms_norm(x, pl["norm_attn"], cfg.norm_eps)
            if cfg.mla is not None:
                attn_out, kv = mla_lib.mla_attention(pl["attn"], h, pos, cfg)
            else:
                q, k, v = _gqa_qkv(pl["attn"], h, positions, cfg)
                q = logical(q, "batch", "seq", "heads", None)
                k = logical(k, "batch", "seq", "kv_heads", None)
                attn_out = attention(
                    q, k, v, pos, pos,
                    window=cfg.sliding_window,
                    softcap=cfg.attn_logit_softcap,
                )
                attn_out = jnp.einsum("bsnh,nhd->bsd", attn_out, pl["attn"]["wo"])
                kv = (k, v)
            x = x + logical(attn_out, "batch", "seq", "embed")
            h2 = rms_norm(x, pl["norm_ffn"], cfg.norm_eps)
            ffn_out, aux_l = _ffn(pl["ffn"], is_moe, h2, cfg)
            x = x + logical(ffn_out, "batch", "seq", "embed")
            return (x, aux + aux_l), (kv if collect_cache else None)

        if cfg.remat in ("block", "full"):
            body = jax.checkpoint(
                body,
                policy=None
                if cfg.remat == "full"
                else jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        return body

    for is_moe, stack in _layer_stacks(cfg, params):
        (x, aux_total), kv = maybe_scan(make_body(is_moe), (x, aux_total), stack)
        if collect_cache:
            caches.append(kv)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = logical(logits, "batch", "seq", "vocab")
    return logits, aux_total, (caches if collect_cache else None)


def loss_fn(cfg: LMConfig, params: dict, tokens: jnp.ndarray):
    """Next-token cross entropy (+ MoE aux, + 1-depth MTP head when on)."""
    logits, aux, _ = forward(cfg, params, tokens)
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll) + aux
    if cfg.mtp_depth > 0:
        # 1-depth MTP (DSv3 §2.2, lightweight variant): combine the current
        # token's embedding with the next token's, project, share the head,
        # and predict token t+2.
        emb_next = jnp.take(params["embed"], tokens[:, 1:], axis=0)
        h = jnp.take(params["embed"], tokens[:, :-1], axis=0)
        cat = jnp.concatenate(
            [rms_norm(h, params["mtp"]["norm"], cfg.norm_eps), emb_next], axis=-1
        )
        h2 = cat @ params["mtp"]["proj"]
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        mtp_logits = h2[:, :-1] @ head
        lp2 = jax.nn.log_softmax(mtp_logits.astype(jnp.float32), axis=-1)
        nll2 = -jnp.take_along_axis(lp2, tokens[:, 2:][..., None], axis=-1)[..., 0]
        loss = loss + 0.1 * jnp.mean(nll2)
    return loss


# ------------------------------------------------------------------ decode
@jax.tree_util.register_pytree_node_class
class DecodeCache:
    """Stacked caches per layer-stack. GQA: k/v (L, B, S_cap, Nkv, H); MLA:
    ckv (L, B, S_cap, r) + kr (L, B, S_cap, d_rope). ``rolling`` caches use
    slot = pos % S_cap (sliding-window archs). kind/s_cap/rolling are pytree
    aux data (static under jit)."""

    def __init__(self, data: tuple, kind: str, s_cap: int, rolling: bool):
        self.data = data
        self.kind = kind
        self.s_cap = s_cap
        self.rolling = rolling

    def tree_flatten(self):
        return (self.data,), (self.kind, self.s_cap, self.rolling)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    def replace_data(self, data: tuple) -> "DecodeCache":
        return DecodeCache(data, self.kind, self.s_cap, self.rolling)

    def __repr__(self):
        return (
            f"DecodeCache(kind={self.kind}, s_cap={self.s_cap}, "
            f"rolling={self.rolling}, n_arrays={len(self.data)})"
        )


def init_cache(
    cfg: LMConfig, batch: int, s_cap: int, *, rolling: bool = False
) -> DecodeCache:
    L = cfg.n_layers
    dt = jnp.dtype(cfg.dtype)
    if rolling:
        assert cfg.sliding_window is not None
        s_cap = min(s_cap, cfg.sliding_window)
    if cfg.mla is not None:
        m = cfg.mla
        data = (
            jnp.zeros((L, batch, s_cap, m.kv_lora_rank), dt),
            jnp.zeros((L, batch, s_cap, m.d_rope), dt),
        )
        return DecodeCache(data, "mla", s_cap, rolling)
    data = (
        jnp.zeros((L, batch, s_cap, cfg.n_kv_heads, cfg.d_head), dt),
        jnp.zeros((L, batch, s_cap, cfg.n_kv_heads, cfg.d_head), dt),
    )
    return DecodeCache(data, "gqa", s_cap, rolling)


def decode_step(
    cfg: LMConfig,
    params: dict,
    cache: DecodeCache,
    token: jnp.ndarray,   # (B, 1) int32
    pos: jnp.ndarray,     # () int32 — tokens already generated
) -> tuple[jnp.ndarray, DecodeCache]:
    """One token for the whole batch; layers scanned with cache as scan xs."""
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    x = logical(x, "batch", None, "embed")
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    S_cap = cache.s_cap
    if cache.rolling:
        slot = pos % S_cap
        key_slots = jnp.arange(S_cap, dtype=jnp.int32)
        key_pos = pos - ((pos - key_slots) % S_cap)  # may be negative: invalid
    else:
        slot = pos
        key_pos = jnp.arange(S_cap, dtype=jnp.int32)
    pos_q = jnp.full((1,), pos, dtype=jnp.int32)

    take = lambda tree, lo, hi: jax.tree.map(lambda a: a[lo:hi], tree)

    def gqa_body(carry, pl, cache_kv, is_moe):
        x = carry
        ck, cv = cache_kv
        h = rms_norm(x, pl["norm_attn"], cfg.norm_eps)
        q, k, v = _gqa_qkv(pl["attn"], h, positions, cfg)
        zero = np.int32(0)  # match slot's int32: dus indices must share one type
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (zero, slot, zero, zero))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (zero, slot, zero, zero))
        if cfg.decode_kv_blocks > 1 and S_cap % cfg.decode_kv_blocks == 0:
            from repro.models.layers import blocked_decode_attention

            attn_out = blocked_decode_attention(
                q, ck, cv, pos_q, key_pos, cfg.decode_kv_blocks,
                window=cfg.sliding_window,
                softcap=cfg.attn_logit_softcap,
            )
        else:
            attn_out = attention(
                q, ck, cv, pos_q, key_pos,
                window=cfg.sliding_window,
                softcap=cfg.attn_logit_softcap,
            )
        attn_out = jnp.einsum("bsnh,nhd->bsd", attn_out, pl["attn"]["wo"])
        x = x + attn_out
        h2 = rms_norm(x, pl["norm_ffn"], cfg.norm_eps)
        ffn_out, _ = _ffn(pl["ffn"], is_moe, h2, cfg)
        return x + ffn_out, (ck, cv)

    def mla_body(carry, pl, cache_kv, is_moe):
        x = carry
        cckv, ckr = cache_kv
        h = rms_norm(x, pl["norm_attn"], cfg.norm_eps)
        attn_out, cckv, ckr = mla_lib.mla_decode(pl["attn"], h, pos, cckv, ckr, cfg)
        x = x + attn_out
        h2 = rms_norm(x, pl["norm_ffn"], cfg.norm_eps)
        ffn_out, _ = _ffn(pl["ffn"], is_moe, h2, cfg)
        return x + ffn_out, (cckv, ckr)

    new_data: list = []
    out_x = x
    offs = 0
    for is_moe, stack in _layer_stacks(cfg, params):
        L_s = stack["norm_attn"].shape[0]
        cache_slice = tuple(take(c, offs, offs + L_s) for c in cache.data)

        def body(carry, xs, _is_moe=is_moe):
            pl, cs = xs
            if cfg.mla is not None:
                return mla_body(carry, pl, cs, _is_moe)
            return gqa_body(carry, pl, cs, _is_moe)

        out_x, cs_new = maybe_scan(body, out_x, (stack, cache_slice))
        new_data.append(cs_new)
        offs += L_s

    joined = tuple(
        jnp.concatenate([nd[i] for nd in new_data], axis=0)
        if len(new_data) > 1
        else new_data[0][i]
        for i in range(len(new_data[0]))
    )
    x = rms_norm(out_x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, DecodeCache(joined, cache.kind, cache.s_cap, cache.rolling)


def prefill(cfg: LMConfig, params: dict, tokens: jnp.ndarray):
    """Prefill: full forward + caches stacked back to (L, B, S, ...)."""
    logits, _, caches = forward(cfg, params, tokens, collect_cache=True)
    joined = tuple(
        jnp.concatenate([c[i] for c in caches], axis=0)
        if len(caches) > 1
        else caches[0][i]
        for i in range(len(caches[0]))
    )
    kind = "mla" if cfg.mla is not None else "gqa"
    return logits, DecodeCache(joined, kind, tokens.shape[1], False)


def init(cfg: LMConfig, key: jax.Array) -> dict:
    return init_params(lm_schema(cfg), key)
