"""xDeepFM (arXiv:1803.05170): sparse embeddings + CIN + DNN.

JAX has no ``nn.EmbeddingBag`` — multi-hot bags are implemented with
``jnp.take`` + masked reduction (and a ragged ``embedding_bag_ragged``
variant built on ``segment_sum``, shared with the graph engine's gather
machinery). Tables are the hot path: (n_fields, vocab, dim) sharded row-wise
across the mesh for serving and field-wise for training (launch/sharding.py).

Heads:
  * ``forward``        — CTR logit (linear + CIN + DNN), train/serve
  * ``retrieval_score``— one query vs N candidates via a factored dot
                         (batched-dot, not a loop — the retrieval_cand cell)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RecSysConfig
from repro.launch.sharding import logical
from repro.models.schema import ParamDef, init_params


def recsys_schema(cfg: RecSysConfig) -> dict:
    F, V, d = cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim
    m = F
    sch: dict = {
        "tables": ParamDef((F, V, d), ("field", "rows", "embed"), "embed"),
        "linear": ParamDef((F, V), ("field", "rows"), "zeros"),
        "bias": ParamDef((), (), "zeros"),
    }
    # CIN: layer k maps (H_{k-1} × m) interaction maps → H_k
    h_prev = m
    cin = {}
    for i, h_k in enumerate(cfg.cin_layers):
        cin[f"w{i}"] = ParamDef((h_prev * m, h_k), (None, "cin"), "he")
        h_prev = h_k
    sch["cin"] = cin
    sch["cin_out"] = ParamDef((sum(cfg.cin_layers), 1), (None, None), "lecun")
    # DNN (final projection to the scalar logit cannot shard its dim-1)
    dims = [F * d] + list(cfg.mlp_layers) + [1]
    dnn = {}
    for i in range(len(dims) - 1):
        last = i == len(dims) - 2
        dnn[f"w{i}"] = ParamDef(
            (dims[i], dims[i + 1]), (None, None if last else "mlp"), "he"
        )
        dnn[f"b{i}"] = ParamDef(
            (dims[i + 1],), (None if last else "mlp",), "zeros"
        )
    sch["dnn"] = dnn
    # retrieval: project user representation and item embedding to a shared space
    sch["user_proj"] = ParamDef((F * d, d), (None, "embed"), "lecun")
    sch["item_proj"] = ParamDef((d, d), (None, "embed"), "lecun")
    return sch


# ------------------------------------------------------------ embedding bag
def embedding_bag(
    tables: jnp.ndarray,   # (F, V, d)
    ids: jnp.ndarray,      # (B, F, bag) int32
    bag_mask: jnp.ndarray,  # (B, F, bag) bool
    *,
    mode: str = "mean",
) -> jnp.ndarray:
    """Fixed-bag EmbeddingBag: take + masked reduce → (B, F, d)."""
    emb = jax.vmap(lambda t, i: jnp.take(t, i, axis=0), in_axes=(0, 1), out_axes=1)(
        tables, ids
    )  # (B, F, bag, d)
    w = bag_mask[..., None].astype(emb.dtype)
    s = jnp.sum(emb * w, axis=2)
    if mode == "sum":
        return s
    return s / jnp.maximum(jnp.sum(w, axis=2), 1.0)


def embedding_bag_ragged(
    table: jnp.ndarray,    # (V, d)
    ids: jnp.ndarray,      # (nnz,) int32
    bag_ids: jnp.ndarray,  # (nnz,) int32 — which output row each id belongs to
    n_bags: int,
    *,
    mode: str = "sum",
) -> jnp.ndarray:
    """Ragged EmbeddingBag via take + segment_sum (torch parity per the
    kernel taxonomy): the per-row bag lengths may vary freely."""
    g = jnp.take(table, ids, axis=0)
    s = jax.ops.segment_sum(g, bag_ids, num_segments=n_bags)
    if mode == "sum":
        return s
    cnt = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), bag_ids, n_bags)
    return s / jnp.maximum(cnt[:, None], 1.0)


# ----------------------------------------------------------------- forward
def _cin(params: dict, x0: jnp.ndarray, layer_dims) -> jnp.ndarray:
    """Compressed Interaction Network. x0: (B, m, d)."""
    B, m, d = x0.shape
    xk = x0
    outs = []
    for i, h_k in enumerate(layer_dims):
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0).reshape(B, -1, d)  # (B, Hk-1*m, d)
        xk = jnp.einsum("bzd,zh->bhd", z, params[f"w{i}"])
        xk = logical(xk, "batch", "cin", None)
        outs.append(jnp.sum(xk, axis=-1))  # sum-pool over d → (B, Hk)
    return jnp.concatenate(outs, axis=-1)


def forward(
    cfg: RecSysConfig,
    params: dict,
    ids: jnp.ndarray,        # (B, F, bag)
    bag_mask: jnp.ndarray,   # (B, F, bag)
) -> jnp.ndarray:
    """CTR logit (B,)."""
    emb = embedding_bag(params["tables"], ids, bag_mask)  # (B, F, d)
    emb = logical(emb, "batch", "field", "embed")
    # first-order linear term (per-field weight lookup)
    lin_w = jax.vmap(
        lambda t, i: jnp.take(t, i, axis=0), in_axes=(0, 1), out_axes=1
    )(params["linear"], ids)  # (B, F, bag)
    lin = jnp.sum(lin_w * bag_mask.astype(lin_w.dtype), axis=(1, 2))
    cin_feat = _cin(params["cin"], emb, cfg.cin_layers)
    cin_logit = (cin_feat @ params["cin_out"])[:, 0]
    flat = emb.reshape(emb.shape[0], -1)
    h = flat
    n_dnn = len(cfg.mlp_layers) + 1
    for i in range(n_dnn):
        h = h @ params["dnn"][f"w{i}"] + params["dnn"][f"b{i}"]
        if i < n_dnn - 1:
            h = jax.nn.relu(h)
            h = logical(h, "batch", "mlp")
    return lin + cin_logit + h[:, 0] + params["bias"]


def loss_fn(cfg, params, ids, bag_mask, labels) -> jnp.ndarray:
    logit = forward(cfg, params, ids, bag_mask)
    z = logit.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    # numerically-stable BCE-with-logits
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def retrieval_score(
    cfg: RecSysConfig,
    params: dict,
    ids: jnp.ndarray,        # (1, F, bag) query features
    bag_mask: jnp.ndarray,
    cand_ids: jnp.ndarray,   # (Nc,) candidate ids in field 0's table
) -> jnp.ndarray:
    """Score one query against Nc candidates with a single batched dot."""
    emb = embedding_bag(params["tables"], ids, bag_mask)      # (1, F, d)
    user = emb.reshape(1, -1) @ params["user_proj"]           # (1, d)
    items = jnp.take(params["tables"][0], cand_ids, axis=0)   # (Nc, d)
    items = items @ params["item_proj"]
    items = logical(items, "candidates", "embed")
    return (items @ user[0]).astype(jnp.float32)              # (Nc,)


def init(cfg: RecSysConfig, key: jax.Array) -> dict:
    return init_params(recsys_schema(cfg), key)
