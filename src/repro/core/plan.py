"""Static query plans.

XLA needs static shapes, so every STwig step carries *capacities* (max roots
per round, per-child candidate cap, output-table rows). These are exactly the
paper's pipelined-join blocks (§4.2 step 3: "we divide the join into multiple
rounds ... We use available memory to control the block size"): a capacity is
a block size, and overflow triggers another round rather than wrong answers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.decompose import (
    Decomposition,
    head_stwig_selection,
    stwig_order_selection,
)
from repro.core.query import QueryGraph, STwig


@dataclasses.dataclass(frozen=True)
class STwigSpec:
    """Static (hashable) spec for one STwig matching step — the jit key."""

    root_label: int
    child_labels: tuple[int, ...]
    root_qnode: int
    child_qnodes: tuple[int, ...]
    root_bound: bool
    child_bound: tuple[bool, ...]
    root_cap: int          # R: roots processed per round
    child_cap: int         # C: candidate children kept per (root, child)
    rows_cap: int          # output table rows per round
    # distinctness constraints, precomputed statically:
    same_label_child_pairs: tuple[tuple[int, int], ...]
    root_label_child_positions: tuple[int, ...]
    child_need: tuple[int, ...]  # per-child multiplicity of its label

    @property
    def n_children(self) -> int:
        return len(self.child_labels)

    @property
    def width(self) -> int:
        return 1 + self.n_children

    @property
    def qnodes(self) -> tuple[int, ...]:
        return (self.root_qnode,) + self.child_qnodes

    @property
    def grid_size(self) -> int:
        return self.child_cap ** self.n_children


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    query: QueryGraph
    specs: tuple[STwigSpec, ...]   # in exploration order
    head: int                      # index into specs
    head_dists: tuple[int, ...]    # d(r_head, r_t) per STwig (Theorem 4)
    join_rows_cap: int
    join_dup_cap: int
    join_block: int
    max_matches: int               # pipeline termination (paper uses 1024)

    @property
    def n_qnodes(self) -> int:
        return self.query.n_nodes


def caps_from_plan(plan: QueryPlan, base: dict | None = None) -> dict:
    """Recover the grow-able capacities from an already-made plan.

    Used as the escalation seed when a caller passed an explicit ``plan``:
    adaptive retries then double the plan's actual capacities instead of
    silently restarting from the `make_plan` defaults (or, worse, not
    retrying at all). Also how the streaming driver reports the caps a
    stream ran at (``MatchStats.final_caps``)."""
    caps = dict(base or {})
    caps.setdefault(
        "child_cap", max((s.child_cap for s in plan.specs), default=8)
    )
    caps.setdefault("join_rows_cap", plan.join_rows_cap)
    caps.setdefault("join_dup_cap", plan.join_dup_cap)
    caps.setdefault("max_matches", plan.max_matches)
    return caps


def _spec_for(
    stwig: STwig,
    bound_before: set[int],
    *,
    root_cap: int,
    child_cap: int,
    emission_budget: int,
) -> STwigSpec:
    k = len(stwig.children)
    # shrink C so the emission grid C^k stays within budget even at R=1
    c = child_cap
    while k > 0 and c > 2 and c**k > emission_budget:
        c -= 1
    grid = c**k if k else 1
    # roots per round sized so one round emits ≤ emission_budget rows;
    # rows_cap = R * grid means per-round emission can NEVER overflow.
    r = max(1, min(root_cap, emission_budget // max(grid, 1)))
    rows_cap = r * max(grid, 1)
    pairs = tuple(
        (i, j)
        for i in range(k)
        for j in range(i + 1, k)
        if stwig.child_labels[i] == stwig.child_labels[j]
    )
    root_kids = tuple(
        i for i in range(k) if stwig.child_labels[i] == stwig.root_label
    )
    need = tuple(
        sum(1 for l in stwig.child_labels if l == stwig.child_labels[i])
        for i in range(k)
    )
    return STwigSpec(
        root_label=stwig.root_label,
        child_labels=stwig.child_labels,
        root_qnode=stwig.root,
        child_qnodes=stwig.children,
        root_bound=stwig.root in bound_before,
        child_bound=tuple(c_ in bound_before for c_ in stwig.children),
        root_cap=r,
        child_cap=c,
        rows_cap=rows_cap,
        same_label_child_pairs=pairs,
        root_label_child_positions=root_kids,
        child_need=need,
    )


def make_plan(
    query: QueryGraph,
    freq: np.ndarray,
    *,
    root_cap: int = 1024,
    child_cap: int = 8,
    emission_budget: int = 1 << 18,
    join_rows_cap: int = 1 << 16,
    join_dup_cap: int = 64,
    join_block: int = 2048,
    max_matches: int = 1024,
    decomposition: Decomposition | None = None,
) -> QueryPlan:
    """Full planning: Algorithm 2 + head selection + static capacities."""
    dec = decomposition or stwig_order_selection(query, freq)
    assert dec.covers(query) and dec.edge_disjoint(), "bad STwig cover"
    head, dists = head_stwig_selection(query, dec)
    specs = tuple(
        _spec_for(
            t,
            bb,
            root_cap=root_cap,
            child_cap=child_cap,
            emission_budget=emission_budget,
        )
        for t, bb in zip(dec.stwigs, dec.bound_before)
    )
    return QueryPlan(
        query=query,
        specs=specs,
        head=head,
        head_dists=tuple(int(d) for d in dists),
        join_rows_cap=join_rows_cap,
        join_dup_cap=join_dup_cap,
        join_block=join_block,
        max_matches=max_matches,
    )
