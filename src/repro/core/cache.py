"""Session-owned executable cache.

Replaces the module-level ``functools.lru_cache`` jit state the engines used
to keep: a `GraphSession` (or a standalone matcher) owns one
`ExecutableCache`, so compiled executables have an explicit lifetime, can be
shared across a batch of queries, and expose hit/miss counters instead of
hiding behind process-global state.

Retrace detection: every build is recorded as a trace event for its logical
key. With ``REPRO_CHECK_RETRACE=1`` in the environment (or
``check_retrace=True``), building the same logical key twice raises
`RetraceError` — one logical key (schemas, caps, block size, kernels name)
must trace exactly once, the invariant the compile/run split and the query
server's executable sharing stand on. `retraced_executables` additionally
catches the silent variant: a *cached* jitted function that re-traced under
one key because a static argument escaped the key (the companion static pass
in `repro.analysis.staticcheck` verifies key coverage at the AST level).
"""
from __future__ import annotations

import os
from collections import Counter, OrderedDict
from typing import Any, Callable, Hashable


class RetraceError(RuntimeError):
    """One logical executable-cache key traced more than once."""


def _env_check_retrace() -> bool:
    return os.environ.get("REPRO_CHECK_RETRACE", "").strip().lower() not in (
        "", "0", "false",
    )


class ExecutableCache:
    """A keyed LRU cache for jitted executables (and their static metadata).

    Keys must be hashable — in practice tuples of static plan state
    (`STwigSpec`, schemas, capacities), exactly what used to key the
    ``lru_cache`` decorators.
    """

    def __init__(self, maxsize: int = 512, *, check_retrace: bool | None = None):
        self.maxsize = int(maxsize)
        self._data: OrderedDict[Hashable, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0
        # one entry per build (= one jit trace of a logical key); survives
        # `clear()` — dropping executables does not erase the trace history
        self.trace_log: list[Hashable] = []
        self._traced: set[Hashable] = set()
        self.check_retrace = (
            _env_check_retrace() if check_retrace is None else bool(check_retrace)
        )
        # staticcheck hook: called as recorder(key, fn, args, kwargs) on
        # every invocation of a cached executable (None = disabled)
        self.recorder: Callable[..., None] | None = None

    def get(self, key: Hashable, build: Callable[[], Any]) -> Any:
        """Return the cached value for ``key``, building (and storing) it on
        a miss. The least-recently-used entry is evicted past ``maxsize``."""
        try:
            value = self._data[key]
        except KeyError:
            self.misses += 1
            if key in self._traced and self.check_retrace:
                raise RetraceError(
                    f"logical key traced twice: {key!r} — the executable for "
                    "this key was already built once this session (rebuilt "
                    "after eviction/clear, or the key is unstable across "
                    "calls); one logical key must trace exactly once"
                )
            self.trace_log.append(key)
            self._traced.add(key)
            value = build()
            self._data[key] = value
            if len(self._data) > self.maxsize:
                self._data.popitem(last=False)
            return self._wrap(key, value)
        self._data.move_to_end(key)
        self.hits += 1
        return self._wrap(key, value)

    # -------------------------------------------------- retrace diagnostics
    def duplicate_traces(self) -> list[Hashable]:
        """Logical keys that traced more than once (empty ⇒ invariant held)."""
        return [k for k, n in Counter(self.trace_log).items() if n > 1]

    def retraced_executables(self) -> list[tuple[Hashable, int]]:
        """Cached jitted executables whose internal jit cache holds more than
        one trace — a static argument varied without varying the cache key."""
        out: list[tuple[Hashable, int]] = []
        for key, value in self._data.items():
            fns = value if isinstance(value, tuple) else (value,)
            for f in fns:
                size_fn = getattr(f, "_cache_size", None)
                if not callable(size_fn):
                    continue
                try:
                    n = int(size_fn())
                except Exception:  # pragma: no cover - jax internals moved
                    continue
                if n > 1:
                    out.append((key, n))
        return out

    def assert_no_retrace(self) -> None:
        """Fail if any logical key traced twice or any cached executable
        silently re-traced under its key."""
        dup = self.duplicate_traces()
        if dup:
            raise RetraceError(f"logical keys traced twice: {dup!r}")
        rex = self.retraced_executables()
        if rex:
            raise RetraceError(
                "executables re-traced under a single cache key (a static "
                f"argument is missing from the key): {rex!r}"
            )

    # ------------------------------------------------------------- plumbing
    def _wrap(self, key: Hashable, value: Any) -> Any:
        """With a recorder installed, intercept executable invocations so
        staticcheck can capture (key, fn, concrete args) for jaxpr walking."""
        rec = self.recorder
        if rec is None:
            return value

        def wrap_fn(f):
            def wrapped(*a, **kw):
                rec(key, f, a, kw)
                return f(*a, **kw)

            return wrapped

        if callable(value):
            return wrap_fn(value)
        if isinstance(value, tuple) and value and callable(value[0]):
            return (wrap_fn(value[0]),) + tuple(value[1:])
        return value

    def clear(self) -> None:
        self._data.clear()

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data
