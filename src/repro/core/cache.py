"""Session-owned executable cache.

Replaces the module-level ``functools.lru_cache`` jit state the engines used
to keep: a `GraphSession` (or a standalone matcher) owns one
`ExecutableCache`, so compiled executables have an explicit lifetime, can be
shared across a batch of queries, and expose hit/miss counters instead of
hiding behind process-global state.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Hashable


class ExecutableCache:
    """A keyed LRU cache for jitted executables (and their static metadata).

    Keys must be hashable — in practice tuples of static plan state
    (`STwigSpec`, schemas, capacities), exactly what used to key the
    ``lru_cache`` decorators.
    """

    def __init__(self, maxsize: int = 512):
        self.maxsize = int(maxsize)
        self._data: OrderedDict[Hashable, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key: Hashable, build: Callable[[], Any]) -> Any:
        """Return the cached value for ``key``, building (and storing) it on
        a miss. The least-recently-used entry is evicted past ``maxsize``."""
        try:
            value = self._data[key]
        except KeyError:
            self.misses += 1
            value = build()
            self._data[key] = value
            if len(self._data) > self.maxsize:
                self._data.popitem(last=False)
            return value
        self._data.move_to_end(key)
        self.hits += 1
        return value

    def clear(self) -> None:
        self._data.clear()

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data
