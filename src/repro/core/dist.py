"""Distributed, parallel subgraph matching (paper §4.3) via shard_map.

Each mesh shard along the ``data`` axis plays the role of one Trinity
machine: it owns one graph partition, explores STwigs over local roots in
parallel, contributes to the replicated binding bitsets with an OR
all-reduce, fetches remote STwig tables bounded by its load set (Theorem 4),
and joins locally. The head STwig (Theorem 5) is never fetched remotely, so
per-shard result sets are provably disjoint — the final union needs no
deduplication, exactly as in the paper.

.. deprecated::
    Constructing `DistributedMatcher` directly is deprecated — open a
    `repro.api.GraphSession` with ``backend="sharded"`` instead.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import join as join_lib
from repro.core.cache import ExecutableCache
from repro.core.collectives import gather_load_set, or_allreduce
from repro.core.engine import MatchResult, grow_caps
from repro.core.match import Bindings, ShardGraph, match_stwig_shard
from repro.core.plan import QueryPlan, STwigSpec, make_plan
from repro.core.query import QueryGraph
from repro.core.result import MatchPage, MatchStats
from repro.graphstore.cluster_graph import ClusterGraphIndex
from repro.graphstore.partition import PartitionedGraph

AXIS = "data"


class _StackedGraph:
    """Device-resident stacked per-shard graph arrays (leading axis = shard)."""

    def __init__(self, pg: PartitionedGraph, mesh: Mesh):
        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        self.labels = jax.device_put(pg.labels, sh)
        self.indptr = jax.device_put(pg.indptr, sh)
        self.indices = jax.device_put(pg.indices, sh)
        self.edge_src = jax.device_put(pg.edge_src, sh)
        self.n_local = jax.device_put(pg.n_local, sh)
        self.n_local_edges = jax.device_put(pg.n_local_edges, sh)
        self.all_labels = jax.device_put(pg.all_labels, rep)

    def tree(self):
        return (
            self.labels,
            self.indptr,
            self.indices,
            self.edge_src,
            self.n_local,
            self.n_local_edges,
            self.all_labels,
        )


def _local_shard_graph(tree) -> ShardGraph:
    labels, indptr, indices, edge_src, n_local, n_local_edges, all_labels = tree
    return ShardGraph(
        labels=labels[0],
        indptr=indptr[0],
        indices=indices[0],
        edge_src=edge_src[0],
        n_local=n_local[0],
        n_local_edges=n_local_edges[0],
        shard_id=lax.axis_index(AXIS).astype(jnp.int32),
        all_labels=all_labels,
    )


@dataclasses.dataclass(eq=False)
class DistributedMatcher:
    """The multi-machine engine. Requires len(mesh.devices) == pg.n_shards."""

    pg: PartitionedGraph
    mesh: Mesh
    cgi: ClusterGraphIndex = None  # type: ignore[assignment]
    cache: ExecutableCache = None  # type: ignore[assignment]

    def __post_init__(self):
        assert self.mesh.devices.size == self.pg.n_shards, (
            self.mesh.devices.size,
            self.pg.n_shards,
        )
        if self.cgi is None:
            self.cgi = ClusterGraphIndex.build(self.pg)
        if self.cache is None:
            self.cache = ExecutableCache()
        self._g = _StackedGraph(self.pg, self.mesh)
        self._rep = NamedSharding(self.mesh, P())

    # ------------------------------------------------------- jitted steps
    def _match_step(self, spec: STwigSpec):
        return self.cache.get(
            ("dist_match", spec), lambda: self._build_match_step(spec)
        )

    def _build_match_step(self, spec: STwigSpec):
        gspecs = (P(AXIS),) * 6 + (P(),)

        def body(tree, bind_words, round_idx):
            g = _local_shard_graph(tree)
            table, contrib = match_stwig_shard(
                g, Bindings(bind_words), spec, round_idx
            )
            contrib_w = or_allreduce(contrib.words, AXIS)
            n_roots_max = lax.pmax(table.n_roots, AXIS)
            overflow_any = lax.pmax(table.overflow.astype(jnp.int32), AXIS) > 0
            return (
                table.cols[None],
                table.valid[None],
                table.n_rows[None],
                contrib_w,
                n_roots_max,
                overflow_any,
            )

        return jax.jit(
            shard_map(
                body,
                mesh=self.mesh,
                in_specs=(gspecs, P(), P()),
                out_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P(), P()),
                # the OR-allreduce butterfly (ppermute) produces replicated
                # values shard_map's static VMA check cannot infer
                check_vma=False,
            )
        )

    def _join_step(
        self,
        schemas: tuple,
        order: tuple[int, ...],
        head_pos: int,
        out_cap: int,
        dup_cap: int,
        caps: tuple[int, ...],
        ring_radii: tuple[int, ...] | None = None,
    ):
        key = ("dist_join", schemas, order, head_pos, out_cap, dup_cap, caps, ring_radii)
        return self.cache.get(
            key,
            lambda: self._build_join_step(
                schemas, order, head_pos, out_cap, dup_cap, ring_radii
            ),
        )

    def _build_join_step(
        self, schemas, order, head_pos, out_cap, dup_cap, ring_radii
    ):
        """One shard_map'd function running the whole join phase per shard.

        ``ring_radii`` (per STwig) selects the §Perf distance-bounded
        ppermute variant: bytes moved scale with the load-set radius instead
        of the cluster size (valid when the cluster graph is a ring — the
        engine checks applicability host-side)."""

        def body(tables, valids, load_masks):
            # tables[i]: (1, cap_i, w_i); load_masks: (1, T, S)
            load = load_masks[0]
            locs: list[join_lib.JoinTable] = []
            for i in range(len(schemas)):
                cols_i, valid_i = tables[i][0], valids[i][0]
                if i == head_pos:
                    cols_f, valid_f = cols_i, valid_i
                elif ring_radii is not None:
                    from repro.core.collectives import gather_load_set_ring

                    cols_f, valid_f = gather_load_set_ring(
                        cols_i, valid_i, load[i], AXIS, ring_radii[i]
                    )
                else:
                    cols_f, valid_f = gather_load_set(
                        cols_i, valid_i, load[i], AXIS
                    )
                locs.append(
                    join_lib.JoinTable(
                        cols=cols_f,
                        valid=valid_f,
                        n_rows=jnp.sum(valid_f, dtype=jnp.int32),
                        overflow=jnp.bool_(False),
                    )
                )
            acc, acc_schema = locs[order[0]], schemas[order[0]]
            for idx in order[1:]:
                acc, acc_schema = join_lib.sort_merge_join(
                    acc,
                    locs[idx],
                    acc_schema,
                    schemas[idx],
                    out_cap=out_cap,
                    dup_cap=dup_cap,
                )
            return acc.cols[None], acc.valid[None], acc.n_rows[None], acc.overflow[None]

        return jax.jit(
            shard_map(
                body,
                mesh=self.mesh,
                in_specs=((P(AXIS),) * len(schemas), (P(AXIS),) * len(schemas), P(AXIS)),
                out_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
            )
        )

    # ----------------------------------------------------------------- API
    def plan(self, query: QueryGraph, **kw) -> QueryPlan:
        return make_plan(query, self.pg.freq, **kw)

    @staticmethod
    def ring_radii_for(load: np.ndarray) -> tuple[int, ...] | None:
        """If every STwig's load set fits in a ring window (shards within
        ring-distance r of each other), return per-STwig radii; else None.
        Hash partitions have complete cluster graphs → None (all-gather is
        optimal there); locality-aware partitions get bounded rings."""
        T, S, _ = load.shape
        radii = []
        for t in range(T):
            ks, js = np.nonzero(load[t])
            d = np.minimum((ks - js) % S, (js - ks) % S)
            r = int(d.max()) if len(d) else 0
            if r > (S - 1) // 2:
                return None
            radii.append(r)
        # beneficial only if strictly smaller than a full gather
        return tuple(radii) if max(radii) < (S - 1) // 2 or S <= 4 else None

    def match(
        self,
        query: QueryGraph,
        *,
        adaptive: bool = True,
        max_retries: int = 6,
        **kw,
    ) -> MatchResult:
        res = self._match_once(query, **kw)
        retries = 0
        while adaptive and not res.complete and retries < max_retries:
            retries += 1
            kw = grow_caps(kw, retries)
            res = self._match_once(query, **kw)
        res.stats.retries = retries
        return res

    def match_stream(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        *,
        block_rows: int = 1024,
        **kw,
    ) -> Iterator[MatchPage]:
        """Streaming pages for the sharded backend.

        The distributed join runs as one fused shard_map program, so blocks
        cannot (yet) be cut inside it: this runs the query once without
        truncation and pages the disjoint per-shard union host-side. The
        page contract (disjoint pages whose union equals the one-shot run)
        matches the local backend; per-block pipelining inside shard_map is
        an open roadmap item.
        """
        if plan is not None:
            plan = dataclasses.replace(plan, max_matches=0)
        res = self._match_once(query, plan=plan, **dict(kw, max_matches=0))
        B = max(1, block_rows)
        for i, lo in enumerate(range(0, res.rows.shape[0], B)):
            yield MatchPage(
                rows=res.rows[lo : lo + B], index=i, complete=res.complete
            )

    def _match_once(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        use_ring: bool = False,
        **kw,
    ) -> MatchResult:
        t0 = time.perf_counter()
        plan = plan or self.plan(query, **kw)
        S = self.pg.n_shards
        n_bits = self.pg.n_total + 1
        bind = jax.device_put(
            Bindings.fresh(plan.n_qnodes, n_bits).words, self._rep
        )

        stats = MatchStats(backend="sharded", n_shards=S)
        overflow = False
        all_cols, all_valids = [], []
        for spec in plan.specs:
            fn = self._match_step(spec)
            round_cols, round_valids = [], []
            contrib = None
            n_rows_tot = 0
            r = 0
            while True:
                cols, valid, n_rows, cw, n_roots_max, ovf = fn(
                    self._g.tree(), bind, jnp.int32(r)
                )
                round_cols.append(cols)
                round_valids.append(valid)
                contrib = cw if contrib is None else jnp.bitwise_or(contrib, cw)
                n_rows_tot += int(jnp.sum(n_rows))
                overflow |= bool(ovf)
                r += 1
                if r * spec.root_cap >= int(n_roots_max):
                    break
            # apply binding replacement on the replicated bitset
            new_bind = bind
            for pos, qn in enumerate(spec.qnodes):
                new_bind = new_bind.at[qn].set(contrib[pos])
            bind = jax.device_put(new_bind, self._rep)
            # concatenate rounds along the per-shard row axis
            all_cols.append(jnp.concatenate(round_cols, axis=1))
            all_valids.append(jnp.concatenate(round_valids, axis=1))
            stats.stwig_rows.append(n_rows_tot)
            stats.rounds.append(r)

        # ---- load sets (Theorem 4) ----------------------------------------
        load = self.cgi.load_sets(query.label_pairs(), plan.head_dists)
        # reorder to (S, T, S): shard-major for sharding along the mesh axis
        load_masks = jax.device_put(
            np.transpose(load, (1, 0, 2)), NamedSharding(self.mesh, P(AXIS))
        )

        schemas = tuple(
            join_lib.Schema(
                qnodes=s.qnodes, qlabels=(s.root_label,) + s.child_labels
            )
            for s in plan.specs
        )
        order = tuple(
            join_lib.select_join_order(list(schemas), stats.stwig_rows)
        )
        caps = tuple(int(c.shape[1]) for c in all_cols)
        ring_radii = self.ring_radii_for(load) if use_ring else None
        jfn = self._join_step(
            schemas,
            order,
            plan.head,
            plan.join_rows_cap,
            plan.join_dup_cap,
            caps,
            ring_radii,
        )
        cols, valid, n_rows, ovf = jfn(
            tuple(all_cols), tuple(all_valids), load_masks
        )
        overflow |= bool(jnp.any(ovf))

        # ---- union across shards (already disjoint) ------------------------
        cols_h = np.asarray(jax.device_get(cols)).reshape(-1, cols.shape[-1])
        valid_h = np.asarray(jax.device_get(valid)).reshape(-1)
        rows_new = cols_h[valid_h]
        if plan.max_matches and rows_new.shape[0] > plan.max_matches:
            rows_new = rows_new[: plan.max_matches]
        final_qnodes = _final_schema(schemas, order)
        perm = np.argsort(np.asarray(final_qnodes))
        rows_new = rows_new[:, perm]
        rows_old = np.where(
            rows_new < self.pg.n_total,
            self.pg.new_to_old[np.minimum(rows_new, self.pg.n_total - 1)],
            -1,
        )
        stats.time_s = time.perf_counter() - t0
        stats.join_order = [schemas[i].qnodes for i in order]
        stats.cache_hits = self.cache.hits
        stats.cache_misses = self.cache.misses
        return MatchResult(
            rows=rows_old.astype(np.int64),
            n_matches=int(rows_old.shape[0]),
            complete=not overflow,
            stats=stats,
        )


def _final_schema(schemas, order) -> tuple[int, ...]:
    acc = schemas[order[0]]
    for i in order[1:]:
        acc, _ = acc.merge(schemas[i])
    return acc.qnodes
