"""Distributed, parallel subgraph matching (paper §4.3) via shard_map.

Each mesh shard along the ``data`` axis plays the role of one Trinity
machine: it owns one graph partition, explores STwigs over local roots in
parallel, contributes to the replicated binding bitsets with an OR
all-reduce, fetches remote STwig tables bounded by its load set (Theorem 4),
and joins locally. The head STwig (Theorem 5) is never fetched remotely, so
per-shard result sets are provably disjoint — the final union needs no
deduplication, exactly as in the paper.

Two join paths share that structure: one fused shard_map program for
one-shot `match` runs, and — for streaming (§6.1) — a run-once phase
(exploration + load-set fetch, results cached on device per query) followed
by a block-parameterized join step that joins only head rows ``[lo, lo+B)``
per shard_map call, so early-stopping consumers skip the remaining blocks.

.. deprecated::
    Constructing `DistributedMatcher` directly is deprecated — open a
    `repro.api.GraphSession` with ``backend="sharded"`` instead.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import join as join_lib
from repro.core.backend import Kernels, resolve_kernels
from repro.core.cache import ExecutableCache
from repro.core.collectives import fetch_load_set, or_allreduce
from repro.core.deprecation import warn_direct_construction
from repro.core.match import Bindings, ShardGraph, match_stwig_shard
from repro.core.plan import QueryPlan, STwigSpec, caps_from_plan, make_plan
from repro.core.query import QueryGraph
from repro.core.result import MatchPage, MatchResult, MatchStats
from repro.core.stream import stream_blocks
from repro.runtime.chaos import ShardFaultError
from repro.runtime.resilience import (
    DegradeReason,
    RetryPolicy,
    adaptive_run,
    stage,
)
from repro.graphstore.cluster_graph import ClusterGraphIndex
from repro.graphstore.partition import PartitionedGraph

AXIS = "data"


class _StackedGraph:
    """Device-resident stacked per-shard graph arrays (leading axis = shard)."""

    def __init__(self, pg: PartitionedGraph, mesh: Mesh):
        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        self.labels = jax.device_put(pg.labels, sh)
        self.indptr = jax.device_put(pg.indptr, sh)
        self.indices = jax.device_put(pg.indices, sh)
        self.edge_src = jax.device_put(pg.edge_src, sh)
        self.n_local = jax.device_put(pg.n_local, sh)
        self.n_local_edges = jax.device_put(pg.n_local_edges, sh)
        self.all_labels = jax.device_put(pg.all_labels, rep)

    def tree(self):
        return (
            self.labels,
            self.indptr,
            self.indices,
            self.edge_src,
            self.n_local,
            self.n_local_edges,
            self.all_labels,
        )


def _local_shard_graph(tree) -> ShardGraph:
    labels, indptr, indices, edge_src, n_local, n_local_edges, all_labels = tree
    return ShardGraph(
        labels=labels[0],
        indptr=indptr[0],
        indices=indices[0],
        edge_src=edge_src[0],
        n_local=n_local[0],
        n_local_edges=n_local_edges[0],
        shard_id=lax.axis_index(AXIS).astype(jnp.int32),
        all_labels=all_labels,
    )


@dataclasses.dataclass(eq=False)
class DistributedMatcher:
    """The multi-machine engine. Requires len(mesh.devices) == pg.n_shards."""

    pg: PartitionedGraph
    mesh: Mesh
    cgi: ClusterGraphIndex = None  # type: ignore[assignment]
    cache: ExecutableCache = None  # type: ignore[assignment]
    kernels: "str | Kernels | None" = None
    # optional seeded fault injector (repro.runtime.chaos): consulted at
    # the host-side fetch/join boundaries, never inside shard_map programs
    chaos: object = None

    def __post_init__(self):
        warn_direct_construction("DistributedMatcher")
        assert self.mesh.devices.size == self.pg.n_shards, (
            self.mesh.devices.size,
            self.pg.n_shards,
        )
        if self.cgi is None:
            self.cgi = ClusterGraphIndex.build(self.pg)
        if self.cache is None:
            self.cache = ExecutableCache()
        # kernel backend for every per-shard dense step; reassignable —
        # executables are keyed by (static spec, kernels.name)
        self.kernels = resolve_kernels(self.kernels)
        if self.chaos is not None:
            self.kernels = self.chaos.wrap_kernels(self.kernels)
        self._g = _StackedGraph(self.pg, self.mesh)
        self._rep = NamedSharding(self.mesh, P())
        # cumulative device invocations of the block-parameterized join step
        # (the streaming path); lets callers assert early stops skip work
        self.join_block_calls = 0

    # ------------------------------------------------------- jitted steps
    def _match_step(self, spec: STwigSpec):
        return self.cache.get(
            ("dist_match", spec, self.kernels.name),
            lambda: self._build_match_step(spec),
        )

    def _build_match_step(self, spec: STwigSpec):
        gspecs = (P(AXIS),) * 6 + (P(),)
        kern = self.kernels

        def body(tree, bind_words, round_idx):
            g = _local_shard_graph(tree)
            table, contrib = match_stwig_shard(
                g, Bindings(bind_words), spec, round_idx, kernels=kern
            )
            contrib_w = or_allreduce(contrib.words, AXIS)
            n_roots_max = lax.pmax(table.n_roots, AXIS)
            overflow_any = lax.pmax(table.overflow.astype(jnp.int32), AXIS) > 0
            return (
                table.cols[None],
                table.valid[None],
                table.n_rows[None],
                contrib_w,
                n_roots_max,
                overflow_any,
            )

        return jax.jit(
            shard_map(
                body,
                mesh=self.mesh,
                in_specs=(gspecs, P(), P()),
                out_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P(), P()),
                # the OR-allreduce butterfly (ppermute) produces replicated
                # values shard_map's static VMA check cannot infer
                check_vma=False,
            )
        )

    def _join_step(
        self,
        schemas: tuple,
        order: tuple[int, ...],
        head_pos: int,
        out_cap: int,
        dup_cap: int,
        caps: tuple[int, ...],
        ring_radii: tuple[int, ...] | None = None,
    ):
        key = (
            "dist_join",
            schemas,
            order,
            head_pos,
            out_cap,
            dup_cap,
            caps,
            ring_radii,
            self.kernels.name,
        )
        return self.cache.get(
            key,
            lambda: self._build_join_step(
                schemas, order, head_pos, out_cap, dup_cap, ring_radii
            ),
        )

    def _build_join_step(
        self, schemas, order, head_pos, out_cap, dup_cap, ring_radii
    ):
        """One shard_map'd function running the whole join phase per shard.

        ``ring_radii`` (per STwig) selects the §Perf distance-bounded
        ppermute variant: bytes moved scale with the load-set radius instead
        of the cluster size (valid when the cluster graph is a ring — the
        engine checks applicability host-side)."""
        kern = self.kernels

        def body(tables, valids, load_masks):
            # tables[i]: (1, cap_i, w_i); load_masks: (1, T, S)
            load = load_masks[0]
            locs: list[join_lib.JoinTable] = []
            for i in range(len(schemas)):
                cols_i, valid_i = tables[i][0], valids[i][0]
                if i == head_pos:
                    cols_f, valid_f = cols_i, valid_i
                else:
                    cols_f, valid_f = fetch_load_set(
                        cols_i,
                        valid_i,
                        load[i],
                        AXIS,
                        ring_radius=None if ring_radii is None else ring_radii[i],
                    )
                locs.append(
                    join_lib.JoinTable(
                        cols=cols_f,
                        valid=valid_f,
                        n_rows=jnp.sum(valid_f, dtype=jnp.int32),
                        overflow=jnp.bool_(False),
                    )
                )
            acc, acc_schema = locs[order[0]], schemas[order[0]]
            for idx in order[1:]:
                acc, acc_schema = join_lib.sort_merge_join(
                    acc,
                    locs[idx],
                    acc_schema,
                    schemas[idx],
                    out_cap=out_cap,
                    dup_cap=dup_cap,
                    kernels=kern,
                )
            return acc.cols[None], acc.valid[None], acc.n_rows[None], acc.overflow[None]

        return jax.jit(
            shard_map(
                body,
                mesh=self.mesh,
                in_specs=((P(AXIS),) * len(schemas), (P(AXIS),) * len(schemas), P(AXIS)),
                out_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
                # Pallas calls inside the mapped body defeat the static
                # replication check (same situation as the match step)
                check_vma=False,
            )
        )

    def _gather_step(
        self,
        n_tables: int,
        head_pos: int,
        caps: tuple[int, ...],
        ring_radii: tuple[int, ...] | None,
    ):
        key = ("dist_gather", n_tables, head_pos, caps, ring_radii)
        return self.cache.get(
            key, lambda: self._build_gather_step(n_tables, head_pos, ring_radii)
        )

    def _build_gather_step(self, n_tables, head_pos, ring_radii):
        """Fetch every non-head STwig table, bounded by the per-shard load
        sets (Theorem 4), in ONE shard_map program.

        Run once per streamed query: the fetched tables are kept on device
        and reused by every subsequent block-join call, so streaming pays
        the communication cost once, not per block. The head table is never
        fetched (Theorem 5) — that is what keeps per-shard pages disjoint.
        """

        def body(tables, valids, load_masks):
            load = load_masks[0]
            outs_c, outs_v = [], []
            for i in range(n_tables):
                if i == head_pos:
                    continue
                cols_f, valid_f = fetch_load_set(
                    tables[i][0],
                    valids[i][0],
                    load[i],
                    AXIS,
                    ring_radius=None if ring_radii is None else ring_radii[i],
                )
                outs_c.append(cols_f[None])
                outs_v.append(valid_f[None])
            return tuple(outs_c), tuple(outs_v)

        n_out = n_tables - 1
        return jax.jit(
            shard_map(
                body,
                mesh=self.mesh,
                in_specs=(
                    (P(AXIS),) * n_tables,
                    (P(AXIS),) * n_tables,
                    P(AXIS),
                ),
                out_specs=((P(AXIS),) * n_out, (P(AXIS),) * n_out),
            )
        )

    def _join_block_step(
        self,
        schemas: tuple,
        order: tuple[int, ...],
        out_cap: int,
        dup_cap: int,
        head_cap: int,
        gathered_caps: tuple[int, ...],
        block_rows: int,
    ):
        key = (
            "dist_join_block",
            schemas,
            order,
            out_cap,
            dup_cap,
            head_cap,
            gathered_caps,
            block_rows,
            self.kernels.name,
        )
        return self.cache.get(
            key,
            lambda: self._build_join_block_step(
                schemas, order, out_cap, dup_cap, block_rows
            ),
        )

    def _build_join_block_step(self, schemas, order, out_cap, dup_cap, block_rows):
        """The block-parameterized join step (paper §6.1 pipelining inside
        shard_map): join only head-table rows ``[lo, lo+block_rows)`` against
        the pre-fetched tables, one shard_map call per block.

        ``lo`` is a replicated traced scalar, so one trace (cached per
        (schemas, caps, block size) in the session's `ExecutableCache`)
        serves every block of the query — blocks differ only in data. The
        join order starts at the head STwig: blocks partition each shard's
        local head rows, every output row descends from exactly one of them,
        and the head is never fetched remotely (Theorem 5), so pages are
        disjoint within a shard and across shards.
        """
        head_pos = order[0]
        kern = self.kernels
        # position of each spec's table in the gathered (non-head) tuple
        g_index = {
            i: j
            for j, i in enumerate(
                i for i in range(len(schemas)) if i != head_pos
            )
        }

        def body(head_cols, head_valid, g_cols, g_valids, lo):
            head = join_lib.JoinTable(
                cols=head_cols[0],
                valid=head_valid[0],
                n_rows=jnp.sum(head_valid[0], dtype=jnp.int32),
                overflow=jnp.bool_(False),
            )
            acc = join_lib.block_table(head, lo, block_rows)
            acc_schema = schemas[head_pos]
            for idx in order[1:]:
                j = g_index[idx]
                tbl = join_lib.JoinTable(
                    cols=g_cols[j][0],
                    valid=g_valids[j][0],
                    n_rows=jnp.sum(g_valids[j][0], dtype=jnp.int32),
                    overflow=jnp.bool_(False),
                )
                acc, acc_schema = join_lib.sort_merge_join(
                    acc,
                    tbl,
                    acc_schema,
                    schemas[idx],
                    out_cap=out_cap,
                    dup_cap=dup_cap,
                    kernels=kern,
                )
            return acc.cols[None], acc.valid[None], acc.n_rows[None], acc.overflow[None]

        n_g = len(schemas) - 1
        return jax.jit(
            shard_map(
                body,
                mesh=self.mesh,
                in_specs=(
                    P(AXIS),
                    P(AXIS),
                    (P(AXIS),) * n_g,
                    (P(AXIS),) * n_g,
                    P(),
                ),
                out_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
                # Pallas calls inside the mapped body defeat the static
                # replication check (same situation as the match step)
                check_vma=False,
            )
        )

    # ----------------------------------------------------------------- API
    def plan(self, query: QueryGraph, **kw) -> QueryPlan:
        return make_plan(query, self.pg.freq, **kw)

    @staticmethod
    def ring_radii_for(load: np.ndarray) -> tuple[int, ...] | None:
        """If every STwig's load set fits in a ring window (shards within
        ring-distance r of each other), return per-STwig radii; else None.
        Hash partitions have complete cluster graphs → None (all-gather is
        optimal there); locality-aware partitions get bounded rings."""
        T, S, _ = load.shape
        radii = []
        for t in range(T):
            ks, js = np.nonzero(load[t])
            d = np.minimum((ks - js) % S, (js - ks) % S)
            r = int(d.max()) if len(d) else 0
            if r > (S - 1) // 2:
                return None
            radii.append(r)
        # beneficial only if strictly smaller than a full gather
        return tuple(radii) if max(radii) < (S - 1) // 2 or S <= 4 else None

    def match(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        *,
        adaptive: bool = True,
        max_retries: int = 6,
        use_ring: bool = False,
        guard: "QueryGuard | None" = None,
        retry_policy: RetryPolicy | None = None,
        **kw,
    ) -> MatchResult:
        """Adaptive matching through the shared resilience loop
        (`repro.runtime.resilience.adaptive_run`): same escalation
        semantics as the local engine, plus ``retry_policy`` also paces
        the fetch-recovery retries when a chaos injector is attached."""
        policy = retry_policy or RetryPolicy(max_retries=max_retries)
        plan0 = plan if plan is not None else self.plan(query, **kw)
        return adaptive_run(
            lambda: self._match_once(
                query, plan=plan0, use_ring=use_ring, retry_policy=policy
            ),
            lambda caps: self._match_once(
                query, use_ring=use_ring, retry_policy=policy, **caps
            ),
            caps_from_plan(plan0, kw),
            n_qnodes=query.n_nodes,
            backend="sharded",
            policy=policy,
            guard=guard,
            adaptive=adaptive,
        )

    def match_stream(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        *,
        block_rows: int = 1024,
        **kw,
    ) -> Iterator[MatchPage]:
        """Truly pipelined streaming for the sharded backend — thin wrapper
        over the shared driver (`repro.core.stream.stream_blocks`), kept
        for direct (deprecated) engine use.

        Exploration and the load-set fetch run once; the per-block join step
        then joins only head-table rows ``[lo, lo+block_rows)`` per
        shard_map call, so a consumer that stops early never pays for the
        remaining blocks' joins. The head STwig is never fetched remotely
        (Theorem 5), so per-shard pages stay disjoint and their union equals
        the one-shot run."""
        yield from stream_blocks(self, query, plan, block_rows=block_rows, **kw)

    # -------------------------------------------------- streaming interface
    def _stream_setup(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        use_ring: bool = False,
        retry_policy: RetryPolicy | None = None,
        **kw,
    ) -> "_ShardedStreamState":
        """The run-once half of a streamed query: exploration, load sets and
        the remote-table fetch all happen here; the returned state caches
        the fetched tables on device for every subsequent block join. A
        shard fault at the fetch (chaos-injected) degrades every page of
        the stream: the state carries the shard-fault reason and the
        driver marks pages ``complete=False``."""
        plan = plan or self.plan(query, **kw)
        stats = MatchStats(backend="sharded", n_shards=self.pg.n_shards)
        with stage(stats, "explore"):
            all_cols, all_valids, overflow = self._explore(plan, stats)
        if self.chaos is not None and self.chaos.forced_overflow():
            overflow = True
        load, load_masks = self._load_masks(query, plan)
        with stage(stats, "fetch"):
            all_valids, fault = self._chaos_gate(
                stats, retry_policy or RetryPolicy(), all_valids, plan.head
            )
        if fault:
            stats.degrade_reason = DegradeReason.SHARD_FAULT.value
        schemas = tuple(
            join_lib.Schema(
                qnodes=s.qnodes, qlabels=(s.root_label,) + s.child_labels
            )
            for s in plan.specs
        )
        # blocks are cut on the head table, so the join order must start
        # there (disjointness across shards comes from head locality)
        order = tuple(
            join_lib.select_join_order(
                list(schemas), stats.stwig_rows, start=plan.head
            )
        )
        ring_radii = self.ring_radii_for(load) if use_ring else None
        caps = tuple(int(c.shape[1]) for c in all_cols)
        with stage(stats, "fetch"):
            if len(schemas) > 1:
                gfn = self._gather_step(
                    len(schemas), plan.head, caps, ring_radii
                )
                g_cols, g_valids = gfn(
                    tuple(all_cols), tuple(all_valids), load_masks
                )
            else:
                g_cols, g_valids = (), ()
        stats.join_order = [schemas[i].qnodes for i in order]
        head_valid = all_valids[plan.head]
        # one host copy of the head validity mask: blocks where no shard has
        # a valid head row are provably empty and skipped without any device
        # call (matching the local backend's empty-block behaviour)
        head_any = np.asarray(jax.device_get(head_valid)).any(axis=0)
        return _ShardedStreamState(
            plan=plan,
            stats=stats,
            schemas=schemas,
            order=order,
            head_cols=all_cols[plan.head],
            head_valid=head_valid,
            head_valid_any=head_any,
            gathered_cols=tuple(g_cols),
            gathered_valids=tuple(g_valids),
            explore_overflow=overflow,
            cap=int(all_cols[plan.head].shape[1]),
        )

    def _stream_block(
        self, state: "_ShardedStreamState", lo: int, block_rows: int
    ) -> tuple[np.ndarray, bool]:
        """One pipelined block: join head rows ``[lo, lo+block_rows)`` of
        every shard against the cached fetched tables and union the
        (disjoint) per-shard results host-side."""
        if not state.head_valid_any[lo : lo + block_rows].any():
            return np.zeros((0, state.plan.n_qnodes), np.int64), False
        if self.chaos is not None:
            d = self.chaos.block_delay()
            if d > 0:
                time.sleep(d)
        jfn = self._join_block_step(
            state.schemas,
            state.order,
            state.plan.join_rows_cap,
            state.plan.join_dup_cap,
            state.cap,
            tuple(int(c.shape[1]) for c in state.gathered_cols),
            block_rows,
        )
        self.join_block_calls += 1
        state.stats.join_blocks += 1
        with stage(state.stats, "join"):
            cols, valid, n_rows, ovf = jfn(
                state.head_cols,
                state.head_valid,
                state.gathered_cols,
                state.gathered_valids,
                jnp.int32(lo),
            )
        with stage(state.stats, "materialize"):
            rows = self._union_rows(
                cols, valid, state.schemas, state.order, max_matches=0
            )
        return rows, bool(jnp.any(ovf))

    # ------------------------------------------------------ execution phases
    def _explore(self, plan: QueryPlan, stats: MatchStats):
        """STwig exploration (Algorithm 2 order) on every shard at once.

        Returns stacked per-shard tables: ``all_cols[i]`` has shape
        (S, rounds_i * rows_cap_i, width_i) with the shard axis leading.
        """
        bind = jax.device_put(
            Bindings.fresh(plan.n_qnodes, self.pg.n_total + 1).words, self._rep
        )
        overflow = False
        all_cols, all_valids = [], []
        for spec in plan.specs:
            fn = self._match_step(spec)
            round_cols, round_valids = [], []
            contrib = None
            n_rows_tot = 0
            n_roots_max = 0
            r = 0
            while True:
                cols, valid, n_rows, cw, n_roots_max, ovf = fn(
                    self._g.tree(), bind, jnp.int32(r)
                )
                round_cols.append(cols)
                round_valids.append(valid)
                contrib = cw if contrib is None else jnp.bitwise_or(contrib, cw)
                n_rows_tot += int(jnp.sum(n_rows))
                overflow |= bool(ovf)
                r += 1
                if r * spec.root_cap >= int(n_roots_max):
                    break
            # apply binding replacement on the replicated bitset
            new_bind = bind
            for pos, qn in enumerate(spec.qnodes):
                new_bind = new_bind.at[qn].set(contrib[pos])
            bind = jax.device_put(new_bind, self._rep)
            # concatenate rounds along the per-shard row axis
            all_cols.append(jnp.concatenate(round_cols, axis=1))
            all_valids.append(jnp.concatenate(round_valids, axis=1))
            stats.stwig_rows.append(n_rows_tot)
            # parity with the local backend's stats (max over shards: the
            # round count is driven by the most loaded shard)
            stats.stwig_roots.append(int(n_roots_max))
            stats.rounds.append(r)
        return all_cols, all_valids, overflow

    def _load_masks(self, query: QueryGraph, plan: QueryPlan):
        """Load sets (Theorem 4), host + device-sharded ``(S, T, S)`` form."""
        load = self.cgi.load_sets(query.label_pairs(), plan.head_dists)
        # reorder to (S, T, S): shard-major for sharding along the mesh axis
        masks = jax.device_put(
            np.transpose(load, (1, 0, 2)), NamedSharding(self.mesh, P(AXIS))
        )
        return load, masks

    def _union_rows(self, cols, valid, schemas, order, max_matches: int) -> np.ndarray:
        """Disjoint per-shard union → host rows of ORIGINAL ids in query-node
        column order (the sharded counterpart of `SubgraphMatcher._materialize`)."""
        cols_h = np.asarray(jax.device_get(cols)).reshape(-1, cols.shape[-1])
        valid_h = np.asarray(jax.device_get(valid)).reshape(-1)
        rows_new = cols_h[valid_h]
        if max_matches and rows_new.shape[0] > max_matches:
            rows_new = rows_new[:max_matches]
        final_qnodes = _final_schema(schemas, order)
        perm = np.argsort(np.asarray(final_qnodes))
        rows_new = rows_new[:, perm]
        rows_old = np.where(
            rows_new < self.pg.n_total,
            self.pg.new_to_old[np.minimum(rows_new, self.pg.n_total - 1)],
            -1,
        )
        return rows_old.astype(np.int64)

    def _match_once(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        use_ring: bool = False,
        retry_policy: RetryPolicy | None = None,
        **kw,
    ) -> MatchResult:
        t0 = time.perf_counter()
        plan = plan or self.plan(query, **kw)
        stats = MatchStats(backend="sharded", n_shards=self.pg.n_shards)
        with stage(stats, "explore"):
            all_cols, all_valids, overflow = self._explore(plan, stats)
        load, load_masks = self._load_masks(query, plan)
        with stage(stats, "fetch"):
            all_valids, fault = self._chaos_gate(
                stats, retry_policy or RetryPolicy(), all_valids, plan.head
            )
        if self.chaos is not None and self.chaos.forced_overflow():
            overflow = True

        schemas = tuple(
            join_lib.Schema(
                qnodes=s.qnodes, qlabels=(s.root_label,) + s.child_labels
            )
            for s in plan.specs
        )
        order = tuple(
            join_lib.select_join_order(list(schemas), stats.stwig_rows)
        )
        caps = tuple(int(c.shape[1]) for c in all_cols)
        ring_radii = self.ring_radii_for(load) if use_ring else None
        with stage(stats, "join"):
            jfn = self._join_step(
                schemas,
                order,
                plan.head,
                plan.join_rows_cap,
                plan.join_dup_cap,
                caps,
                ring_radii,
            )
            cols, valid, n_rows, ovf = jfn(
                tuple(all_cols), tuple(all_valids), load_masks
            )
            overflow |= bool(jnp.any(ovf))

        # ---- union across shards (already disjoint) ------------------------
        with stage(stats, "materialize"):
            rows_old = self._union_rows(
                cols, valid, schemas, order, plan.max_matches
            )
        stats.time_s = time.perf_counter() - t0
        stats.join_order = [schemas[i].qnodes for i in order]
        stats.cache_hits = self.cache.hits
        stats.cache_misses = self.cache.misses
        if fault:
            stats.degrade_reason = DegradeReason.SHARD_FAULT.value
        return MatchResult(
            rows=rows_old,
            n_matches=int(rows_old.shape[0]),
            complete=not (overflow or fault),
            stats=stats,
        )

    # -------------------------------------------------- fault handling
    def _chaos_gate(
        self, stats: MatchStats, policy: RetryPolicy, all_valids, head_pos: int
    ):
        """The host-side fetch boundary: consult the chaos injector (when
        attached), retry dead fetches with the policy's jittered backoff,
        and degrade to the surviving shards' rows by masking the faulty
        shard's stacked validity. Returns (all_valids, faulted). Runs
        BEFORE the gather/join shard_map programs — an SPMD program can't
        lose a shard mid-flight, a memory cloud loses it at fetch time."""
        stats.shard_health = {k: "ok" for k in range(self.pg.n_shards)}
        chaos = self.chaos
        if chaos is None:
            return all_valids, False
        fault = False
        ev = chaos.fetch_delay()
        if ev is not None:
            k, d = ev
            time.sleep(d)
            stats.shard_health[k] = "slow"
        attempt = 0
        while True:
            try:
                chaos.try_fetch()
                if attempt > 0:
                    stats.shard_health[chaos.config.dead_shard] = "recovered"
                break
            except ShardFaultError as e:
                if attempt >= policy.fetch_retries:
                    all_valids = self._mask_shard(all_valids, e.shard)
                    stats.shard_health[e.shard] = "dead"
                    fault = True
                    break
                policy.sleep(attempt, policy.fetch_backoff_s)
                attempt += 1
                stats.fetch_retries += 1
        tr = chaos.truncation()
        if tr is not None:
            k, keep = tr
            all_valids = self._mask_shard(
                all_valids, k, head_pos=head_pos, keep_frac=keep
            )
            if stats.shard_health.get(k) == "ok":
                stats.shard_health[k] = "truncated"
            fault = True
        return all_valids, fault

    def _mask_shard(
        self,
        all_valids,
        shard: int,
        head_pos: int | None = None,
        keep_frac: float | None = None,
    ):
        """Invalidate (all of, or the tail of) one shard's rows in the
        stacked validity masks — host-side, so results built from the
        masked tables are a correct subset of the true row set, never a
        wrong one. With ``keep_frac`` (the truncated-payload fault) the
        head table is left intact: it is never fetched (Theorem 5), so a
        transfer can't truncate it."""
        sh = NamedSharding(self.mesh, P(AXIS))
        out = []
        for i, v in enumerate(all_valids):
            if keep_frac is not None and i == head_pos:
                out.append(v)
                continue
            vh = np.array(jax.device_get(v))
            if keep_frac is None:
                vh[shard] = False
            else:
                vh[shard, int(keep_frac * vh.shape[1]):] = False
            out.append(jax.device_put(vh, sh))
        return out


@dataclasses.dataclass(eq=False)
class _ShardedStreamState:
    """Per-query stream state for the sharded backend.

    Exploration and the load-set fetch ran once; ``head_cols``/``head_valid``
    are the stacked (S, head_cap, w) local head tables and
    ``gathered_cols``/``gathered_valids`` the per-shard fetched tables, all
    kept on device. `DistributedMatcher._stream_block` joins head rows
    ``[lo, lo+B)`` per call — the lazy half of the pipeline.
    """

    plan: QueryPlan
    stats: MatchStats
    schemas: tuple
    order: tuple[int, ...]
    head_cols: jnp.ndarray
    head_valid: jnp.ndarray
    head_valid_any: np.ndarray  # (cap,) host bool: any shard valid at row i
    gathered_cols: tuple
    gathered_valids: tuple
    explore_overflow: bool
    cap: int  # per-shard head-table row capacity (the block loop bound)


def _final_schema(schemas, order) -> tuple[int, ...]:
    acc = schemas[order[0]]
    for i in order[1:]:
        acc, _ = acc.merge(schemas[i])
    return acc.qnodes
