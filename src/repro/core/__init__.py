"""The paper's contribution: index-free distributed subgraph matching.

Public API:
    QueryGraph, STwig            — query model (§2.1, §4.1)
    stwig_order_selection        — Algorithm 2 (decomposition + ordering)
    make_plan / QueryPlan        — static capacity planning
    SubgraphMatcher              — single-shard engine
    DistributedMatcher           — shard_map engine w/ head-STwig + load sets
"""
from repro.core.query import QueryGraph, STwig
from repro.core.decompose import (
    Decomposition,
    f_values,
    head_stwig_selection,
    stwig_order_selection,
)
from repro.core.plan import QueryPlan, STwigSpec, make_plan
from repro.core.engine import MatchResult, SubgraphMatcher

__all__ = [
    "QueryGraph",
    "STwig",
    "Decomposition",
    "f_values",
    "head_stwig_selection",
    "stwig_order_selection",
    "QueryPlan",
    "STwigSpec",
    "make_plan",
    "MatchResult",
    "SubgraphMatcher",
]
