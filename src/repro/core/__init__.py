"""The paper's contribution: index-free distributed subgraph matching.

Public API:
    QueryGraph, STwig            — query model (§2.1, §4.1)
    stwig_order_selection        — Algorithm 2 (decomposition + ordering)
    make_plan / QueryPlan        — static capacity planning
    MatchResult / MatchStats     — typed results (repro.core.result)
    ExecutableCache              — session-owned jit cache (repro.core.cache)
    Kernels / get_kernels        — kernel backend registry (repro.core.backend)
    SubgraphMatcher              — single-shard engine (prefer repro.api)
    DistributedMatcher           — shard_map engine w/ head-STwig + load sets

The preferred entry point is `repro.api.GraphSession`, a facade over both
engines with an explicit compile/run split.
"""
from repro.core.query import QueryGraph, STwig
from repro.core.decompose import (
    Decomposition,
    f_values,
    head_stwig_selection,
    stwig_order_selection,
)
from repro.core.plan import QueryPlan, STwigSpec, make_plan
from repro.core.backend import (
    Kernels,
    available_backends,
    get_kernels,
    register_backend,
    resolve_kernels,
)
from repro.core.cache import ExecutableCache
from repro.core.result import MatchPage, MatchResult, MatchStats
from repro.core.engine import SubgraphMatcher

__all__ = [
    "QueryGraph",
    "STwig",
    "Decomposition",
    "f_values",
    "head_stwig_selection",
    "stwig_order_selection",
    "QueryPlan",
    "STwigSpec",
    "make_plan",
    "Kernels",
    "available_backends",
    "get_kernels",
    "register_backend",
    "resolve_kernels",
    "ExecutableCache",
    "MatchResult",
    "MatchStats",
    "MatchPage",
    "SubgraphMatcher",
]
