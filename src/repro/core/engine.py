"""Single-shard subgraph matching engine (the per-machine executor).

Orchestration is host-side (the paper's query proxy); every dense step is a
jitted JAX function cached by its static plan spec. The distributed engine
(`repro.core.dist`) wraps the same match/join steps in ``shard_map``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import join as join_lib
from repro.core.match import (
    Bindings,
    ShardGraph,
    STwigTable,
    apply_binding_update,
    match_stwig_shard,
)
from repro.core.plan import QueryPlan, STwigSpec, make_plan
from repro.core.query import QueryGraph
from repro.graphstore.partition import PartitionedGraph


@dataclasses.dataclass
class MatchResult:
    rows: np.ndarray          # (n_matches, n_qnodes) ORIGINAL node ids
    n_matches: int
    complete: bool            # False if any capacity overflowed (partial set)
    stats: dict[str, Any]


@functools.lru_cache(maxsize=512)
def _jit_match(spec: STwigSpec):
    return jax.jit(functools.partial(match_stwig_shard, spec=spec))


@functools.lru_cache(maxsize=512)
def _jit_join(schema_a, schema_b, out_cap: int, dup_cap: int):
    """Returns (jitted join fn, merged schema). The schema is static — it
    must not pass through jit."""
    merged, _ = schema_a.merge(schema_b)
    fn = jax.jit(
        lambda a, b: join_lib.sort_merge_join(
            a, b, schema_a, schema_b, out_cap=out_cap, dup_cap=dup_cap
        )[0]
    )
    return fn, merged


def _concat_tables(tables: list[STwigTable], rows_cap: int) -> join_lib.JoinTable:
    """Concatenate per-round tables into one join input (host-orchestrated)."""
    cols = jnp.concatenate([t.cols for t in tables], axis=0)
    valid = jnp.concatenate([t.valid for t in tables], axis=0)
    n_rows = sum((t.n_rows for t in tables), jnp.int32(0))
    overflow = functools.reduce(
        jnp.logical_or, [t.overflow for t in tables], jnp.bool_(False)
    )
    return join_lib.JoinTable(cols=cols, valid=valid, n_rows=n_rows, overflow=overflow)


class SubgraphMatcher:
    """Single-device matcher over a (possibly 1-shard) partitioned graph."""

    def __init__(self, pg: PartitionedGraph, shard: int = 0):
        assert 0 <= shard < pg.n_shards
        self.pg = pg
        self.g = ShardGraph(
            labels=jnp.asarray(pg.labels[shard]),
            indptr=jnp.asarray(pg.indptr[shard]),
            indices=jnp.asarray(pg.indices[shard]),
            edge_src=jnp.asarray(pg.edge_src[shard]),
            n_local=jnp.int32(pg.n_local[shard]),
            n_local_edges=jnp.int32(pg.n_local_edges[shard]),
            shard_id=jnp.int32(shard),
            all_labels=jnp.asarray(pg.all_labels),
        )

    # ------------------------------------------------------------------ API
    def plan(self, query: QueryGraph, **kw) -> QueryPlan:
        return make_plan(query, self.pg.freq, **kw)

    def match(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        *,
        adaptive: bool = True,
        max_retries: int = 6,
        **kw,
    ) -> MatchResult:
        """Match with adaptive capacity growth: if any block capacity
        overflows (paper §4.2: block sizes are set by available memory), the
        plan is re-made with doubled capacities and the query re-runs. With
        ``adaptive=False`` the first (possibly partial) result is returned
        with ``complete=False`` — the paper's first-K pipelined semantics."""
        res = self._match_once(query, plan, **kw)
        retries = 0
        while adaptive and plan is None and not res.complete and retries < max_retries:
            retries += 1
            kw = dict(kw)
            kw["child_cap"] = 2 * kw.get("child_cap", 8) * retries
            kw["join_rows_cap"] = 4 * kw.get("join_rows_cap", 1 << 16)
            kw["join_dup_cap"] = 4 * kw.get("join_dup_cap", 64)
            res = self._match_once(query, None, **kw)
        res.stats["retries"] = retries
        return res

    def _match_once(
        self, query: QueryGraph, plan: QueryPlan | None = None, **kw
    ) -> MatchResult:
        t0 = time.perf_counter()
        plan = plan or self.plan(query, **kw)
        n_bits = self.pg.n_total + 1
        bind = Bindings.fresh(plan.n_qnodes, n_bits)

        # ---- exploration: STwigs in Algorithm-2 order ----------------------
        tables: list[join_lib.JoinTable] = []
        schemas: list[join_lib.Schema] = []
        stats: dict[str, Any] = {"stwig_rows": [], "stwig_roots": [], "rounds": []}
        overflow = False
        for spec in plan.specs:
            fn = _jit_match(spec)
            round_tables: list[STwigTable] = []
            contrib = None
            r = 0
            while True:
                table, c = fn(self.g, bind, round_idx=jnp.int32(r))
                round_tables.append(table)
                cw = c.words
                contrib = cw if contrib is None else jnp.bitwise_or(contrib, cw)
                n_roots = int(table.n_roots)
                r += 1
                if r * spec.root_cap >= n_roots:
                    break
            bind = apply_binding_update(bind, spec, contrib)
            jt = _concat_tables(round_tables, spec.rows_cap)
            tables.append(jt)
            schemas.append(
                join_lib.Schema(
                    qnodes=spec.qnodes,
                    qlabels=(spec.root_label,) + spec.child_labels,
                )
            )
            stats["stwig_rows"].append(int(jt.n_rows))
            stats["stwig_roots"].append(int(round_tables[0].n_roots))
            stats["rounds"].append(r)
            overflow |= bool(jax.device_get(jt.overflow))

        # ---- join phase ----------------------------------------------------
        counts = stats["stwig_rows"]
        order = join_lib.select_join_order(schemas, counts)
        acc, acc_schema = tables[order[0]], schemas[order[0]]
        for idx in order[1:]:
            fn, merged = _jit_join(
                acc_schema, schemas[idx], plan.join_rows_cap, plan.join_dup_cap
            )
            acc, acc_schema = fn(acc, tables[idx]), merged
        overflow |= bool(jax.device_get(acc.overflow))

        # ---- materialize (original ids, query-node column order) ----------
        cols = np.asarray(jax.device_get(acc.cols))
        valid = np.asarray(jax.device_get(acc.valid))
        rows_new = cols[valid]
        if plan.max_matches and rows_new.shape[0] > plan.max_matches:
            rows_new = rows_new[: plan.max_matches]
        perm = np.argsort(np.asarray(acc_schema.qnodes))
        rows_new = rows_new[:, perm]
        rows_old = np.where(
            rows_new < self.pg.n_total, self.pg.new_to_old[np.minimum(rows_new, self.pg.n_total - 1)], -1
        )
        stats["join_order"] = [tuple(schemas[i].qnodes) for i in order]
        stats["time_s"] = time.perf_counter() - t0
        stats["n_join_rows"] = int(acc.n_rows)
        return MatchResult(
            rows=rows_old.astype(np.int64),
            n_matches=int(rows_old.shape[0]),
            complete=not overflow,
            stats=stats,
        )
