"""Single-shard subgraph matching engine (the per-machine executor).

Orchestration is host-side (the paper's query proxy); every dense step is a
jitted JAX function keyed by its static plan spec in a session-owned
`ExecutableCache`. The distributed engine (`repro.core.dist`) wraps the same
match/join steps in ``shard_map``.

.. deprecated::
    Constructing `SubgraphMatcher` directly is deprecated — open a
    `repro.api.GraphSession` instead; it selects the backend, owns the
    executable cache, and exposes the compile/run split.
"""
from __future__ import annotations

import functools
import time
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import join as join_lib
from repro.core.cache import ExecutableCache
from repro.core.match import (
    Bindings,
    ShardGraph,
    STwigTable,
    apply_binding_update,
    match_stwig_shard,
)
from repro.core.plan import QueryPlan, STwigSpec, make_plan
from repro.core.query import QueryGraph
from repro.core.result import MatchPage, MatchResult, MatchStats
from repro.graphstore.partition import PartitionedGraph

__all__ = ["MatchResult", "MatchStats", "MatchPage", "SubgraphMatcher"]


def _concat_tables(tables: list[STwigTable]) -> join_lib.JoinTable:
    """Concatenate per-round tables into one join input (host-orchestrated).

    The concatenated capacity is ``n_rounds * spec.rows_cap`` — deliberately
    larger than the per-round plan capacity: rounds exist precisely so one
    round's block never overflows, and the join phase's own ``out_cap``
    bounds everything downstream.
    """
    cols = jnp.concatenate([t.cols for t in tables], axis=0)
    valid = jnp.concatenate([t.valid for t in tables], axis=0)
    n_rows = sum((t.n_rows for t in tables), jnp.int32(0))
    overflow = functools.reduce(
        jnp.logical_or, [t.overflow for t in tables], jnp.bool_(False)
    )
    return join_lib.JoinTable(cols=cols, valid=valid, n_rows=n_rows, overflow=overflow)


def grow_caps(caps: dict, retries: int) -> dict:
    """One step of adaptive capacity growth (paper §4.2: block sizes are set
    by available memory; overflow doubles them and re-runs)."""
    caps = dict(caps)
    caps["child_cap"] = 2 * caps.get("child_cap", 8) * retries
    caps["join_rows_cap"] = 4 * caps.get("join_rows_cap", 1 << 16)
    caps["join_dup_cap"] = 4 * caps.get("join_dup_cap", 64)
    return caps


class SubgraphMatcher:
    """Single-device matcher over a (possibly 1-shard) partitioned graph."""

    def __init__(
        self,
        pg: PartitionedGraph,
        shard: int = 0,
        *,
        cache: ExecutableCache | None = None,
    ):
        assert 0 <= shard < pg.n_shards
        self.pg = pg
        self.cache = cache if cache is not None else ExecutableCache()
        self.g = ShardGraph(
            labels=jnp.asarray(pg.labels[shard]),
            indptr=jnp.asarray(pg.indptr[shard]),
            indices=jnp.asarray(pg.indices[shard]),
            edge_src=jnp.asarray(pg.edge_src[shard]),
            n_local=jnp.int32(pg.n_local[shard]),
            n_local_edges=jnp.int32(pg.n_local_edges[shard]),
            shard_id=jnp.int32(shard),
            all_labels=jnp.asarray(pg.all_labels),
        )

    # -------------------------------------------------- cached executables
    def _match_fn(self, spec: STwigSpec):
        return self.cache.get(
            ("match", spec),
            lambda: jax.jit(functools.partial(match_stwig_shard, spec=spec)),
        )

    def _join_fn(self, schema_a, schema_b, out_cap: int, dup_cap: int):
        """Returns (jitted join fn, merged schema). The schema is static — it
        must not pass through jit."""

        def build():
            merged, _ = schema_a.merge(schema_b)
            fn = jax.jit(
                lambda a, b: join_lib.sort_merge_join(
                    a, b, schema_a, schema_b, out_cap=out_cap, dup_cap=dup_cap
                )[0]
            )
            return fn, merged

        return self.cache.get(("join", schema_a, schema_b, out_cap, dup_cap), build)

    # ------------------------------------------------------------------ API
    def plan(self, query: QueryGraph, **kw) -> QueryPlan:
        return make_plan(query, self.pg.freq, **kw)

    def match(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        *,
        adaptive: bool = True,
        max_retries: int = 6,
        **kw,
    ) -> MatchResult:
        """Match with adaptive capacity growth: if any block capacity
        overflows (paper §4.2: block sizes are set by available memory), the
        plan is re-made with doubled capacities and the query re-runs. With
        ``adaptive=False`` the first (possibly partial) result is returned
        with ``complete=False`` — the paper's first-K pipelined semantics."""
        res = self._match_once(query, plan, **kw)
        retries = 0
        while adaptive and plan is None and not res.complete and retries < max_retries:
            retries += 1
            kw = grow_caps(kw, retries)
            res = self._match_once(query, None, **kw)
        res.stats.retries = retries
        return res

    def match_stream(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        *,
        block_rows: int = 1024,
        **kw,
    ) -> Iterator[MatchPage]:
        """Pipelined first-K execution (paper §6.1): after exploration, the
        first table in join order is fed through the join chain in blocks of
        ``block_rows`` rows and each block's matches are yielded as soon as
        they materialize. A consumer that stops after K matches never pays
        for the joins of the remaining blocks — unlike `match`, which joins
        everything and truncates afterwards.

        Blocks partition the first table's rows, and every output row of a
        join descends from exactly one build-side row, so pages are disjoint
        and their union over all blocks equals the one-shot join. Streaming
        is inherently first-K: there is no adaptive retry, and a page whose
        block overflowed a capacity reports ``complete=False``.
        """
        plan = plan or self.plan(query, **kw)
        stats = MatchStats(backend="local")
        tables, schemas, explore_overflow = self._explore(plan, stats)
        order = join_lib.select_join_order(schemas, stats.stwig_rows)

        first = tables[order[0]]
        cap = int(first.cols.shape[0])
        B = max(1, min(block_rows, cap))
        page_idx = 0
        for lo in range(0, cap, B):
            hi = min(cap, lo + B)
            blk_valid = first.valid[lo:hi]
            n_blk = int(jax.device_get(jnp.sum(blk_valid, dtype=jnp.int32)))
            if n_blk == 0:
                continue
            acc = join_lib.JoinTable(
                cols=first.cols[lo:hi],
                valid=blk_valid,
                n_rows=jnp.int32(n_blk),
                overflow=jnp.bool_(False),
            )
            acc_schema = schemas[order[0]]
            for idx in order[1:]:
                fn, merged = self._join_fn(
                    acc_schema, schemas[idx], plan.join_rows_cap, plan.join_dup_cap
                )
                acc, acc_schema = fn(acc, tables[idx]), merged
            rows = self._materialize(acc, acc_schema, max_matches=0)
            if rows.shape[0] == 0:
                continue
            yield MatchPage(
                rows=rows,
                index=page_idx,
                complete=not (explore_overflow or bool(jax.device_get(acc.overflow))),
            )
            page_idx += 1

    # ------------------------------------------------------ execution phases
    def _explore(
        self, plan: QueryPlan, stats: MatchStats
    ) -> tuple[list[join_lib.JoinTable], list[join_lib.Schema], bool]:
        """STwig exploration in Algorithm-2 order → per-STwig join inputs."""
        n_bits = self.pg.n_total + 1
        bind = Bindings.fresh(plan.n_qnodes, n_bits)
        tables: list[join_lib.JoinTable] = []
        schemas: list[join_lib.Schema] = []
        overflow = False
        for spec in plan.specs:
            fn = self._match_fn(spec)
            round_tables: list[STwigTable] = []
            contrib = None
            r = 0
            while True:
                table, c = fn(self.g, bind, round_idx=jnp.int32(r))
                round_tables.append(table)
                cw = c.words
                contrib = cw if contrib is None else jnp.bitwise_or(contrib, cw)
                n_roots = int(table.n_roots)
                r += 1
                if r * spec.root_cap >= n_roots:
                    break
            bind = apply_binding_update(bind, spec, contrib)
            jt = _concat_tables(round_tables)
            tables.append(jt)
            schemas.append(
                join_lib.Schema(
                    qnodes=spec.qnodes,
                    qlabels=(spec.root_label,) + spec.child_labels,
                )
            )
            stats.stwig_rows.append(int(jt.n_rows))
            stats.stwig_roots.append(int(round_tables[0].n_roots))
            stats.rounds.append(r)
            overflow |= bool(jax.device_get(jt.overflow))
        return tables, schemas, overflow

    def _materialize(
        self, acc: join_lib.JoinTable, acc_schema: join_lib.Schema, max_matches: int
    ) -> np.ndarray:
        """Device join table → host rows of ORIGINAL ids in query-node order."""
        cols = np.asarray(jax.device_get(acc.cols))
        valid = np.asarray(jax.device_get(acc.valid))
        rows_new = cols[valid]
        if max_matches and rows_new.shape[0] > max_matches:
            rows_new = rows_new[:max_matches]
        perm = np.argsort(np.asarray(acc_schema.qnodes))
        rows_new = rows_new[:, perm]
        rows_old = np.where(
            rows_new < self.pg.n_total,
            self.pg.new_to_old[np.minimum(rows_new, self.pg.n_total - 1)],
            -1,
        )
        return rows_old.astype(np.int64)

    def _match_once(
        self, query: QueryGraph, plan: QueryPlan | None = None, **kw
    ) -> MatchResult:
        t0 = time.perf_counter()
        plan = plan or self.plan(query, **kw)
        stats = MatchStats(backend="local")
        tables, schemas, overflow = self._explore(plan, stats)

        # ---- join phase ----------------------------------------------------
        order = join_lib.select_join_order(schemas, stats.stwig_rows)
        acc, acc_schema = tables[order[0]], schemas[order[0]]
        for idx in order[1:]:
            fn, merged = self._join_fn(
                acc_schema, schemas[idx], plan.join_rows_cap, plan.join_dup_cap
            )
            acc, acc_schema = fn(acc, tables[idx]), merged
        overflow |= bool(jax.device_get(acc.overflow))

        # ---- materialize (original ids, query-node column order) ----------
        rows_old = self._materialize(acc, acc_schema, plan.max_matches)
        stats.join_order = [tuple(schemas[i].qnodes) for i in order]
        stats.time_s = time.perf_counter() - t0
        stats.n_join_rows = int(acc.n_rows)
        stats.cache_hits = self.cache.hits
        stats.cache_misses = self.cache.misses
        return MatchResult(
            rows=rows_old,
            n_matches=int(rows_old.shape[0]),
            complete=not overflow,
            stats=stats,
        )
