"""Single-shard subgraph matching engine (the per-machine executor).

Orchestration is host-side (the paper's query proxy); every dense step is a
jitted JAX function keyed by its static plan spec in a session-owned
`ExecutableCache`. The distributed engine (`repro.core.dist`) wraps the same
match/join steps in ``shard_map``.

.. deprecated::
    Constructing `SubgraphMatcher` directly is deprecated — open a
    `repro.api.GraphSession` instead; it selects the backend, owns the
    executable cache, and exposes the compile/run split.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import join as join_lib
from repro.core.backend import Kernels, resolve_kernels
from repro.core.cache import ExecutableCache
from repro.core.deprecation import warn_direct_construction
from repro.core.match import (
    Bindings,
    ShardGraph,
    STwigTable,
    apply_binding_update,
    match_stwig_shard,
)
from repro.core.plan import QueryPlan, STwigSpec, caps_from_plan, make_plan
from repro.core.query import QueryGraph
from repro.core.result import MatchPage, MatchResult, MatchStats
from repro.core.stream import stream_blocks
from repro.graphstore.partition import PartitionedGraph
from repro.runtime.resilience import RetryPolicy, adaptive_run, grow_caps, stage

__all__ = [
    "MatchResult",
    "MatchStats",
    "MatchPage",
    "SubgraphMatcher",
    # canonical homes are repro.runtime.resilience / repro.core.plan;
    # re-exported here for the engine-level callers that always used them
    "grow_caps",
    "caps_from_plan",
]


def _concat_tables(tables: list[STwigTable]) -> join_lib.JoinTable:
    """Concatenate per-round tables into one join input (host-orchestrated).

    The concatenated capacity is ``n_rounds * spec.rows_cap`` — deliberately
    larger than the per-round plan capacity: rounds exist precisely so one
    round's block never overflows, and the join phase's own ``out_cap``
    bounds everything downstream.
    """
    cols = jnp.concatenate([t.cols for t in tables], axis=0)
    valid = jnp.concatenate([t.valid for t in tables], axis=0)
    n_rows = sum((t.n_rows for t in tables), jnp.int32(0))
    overflow = functools.reduce(
        jnp.logical_or, [t.overflow for t in tables], jnp.bool_(False)
    )
    return join_lib.JoinTable(cols=cols, valid=valid, n_rows=n_rows, overflow=overflow)


@dataclasses.dataclass(eq=False)
class _LocalStreamState:
    """Per-query stream state for the local backend: exploration ran once,
    tables/schemas/order are fixed, and blocks of the first table in join
    order are joined lazily by `SubgraphMatcher._stream_block`."""

    plan: QueryPlan
    stats: MatchStats
    tables: list
    schemas: list
    order: tuple[int, ...]
    explore_overflow: bool
    cap: int  # row capacity of the blocked table (the block loop bound)
    valid_host: np.ndarray  # (cap,) host bool mask of the blocked table


class SubgraphMatcher:
    """Single-device matcher over a (possibly 1-shard) partitioned graph."""

    def __init__(
        self,
        pg: PartitionedGraph,
        shard: int = 0,
        *,
        cache: ExecutableCache | None = None,
        kernels: "str | Kernels | None" = None,
        chaos=None,
    ):
        warn_direct_construction("SubgraphMatcher")
        assert 0 <= shard < pg.n_shards
        self.pg = pg
        self.cache = cache if cache is not None else ExecutableCache()
        # the kernel backend every dense step draws from; reassignable at
        # any time — executables are keyed by (static spec, kernels.name),
        # so switching backends mid-session cannot poison the cache
        self.kernels = resolve_kernels(kernels)
        # optional seeded fault injector (repro.runtime.chaos). The local
        # backend has no fetches, so only slow-step delays and forced
        # overflow apply; the wrapped kernels' distinct name keeps chaos
        # executables out of clean cache entries.
        self.chaos = chaos
        if chaos is not None:
            self.kernels = chaos.wrap_kernels(self.kernels)
        # cumulative device invocations of the per-block join chain (the
        # streaming path); lets callers assert early-stopped streams skip work
        self.join_block_calls = 0
        self.g = ShardGraph(
            labels=jnp.asarray(pg.labels[shard]),
            indptr=jnp.asarray(pg.indptr[shard]),
            indices=jnp.asarray(pg.indices[shard]),
            edge_src=jnp.asarray(pg.edge_src[shard]),
            n_local=jnp.int32(pg.n_local[shard]),
            n_local_edges=jnp.int32(pg.n_local_edges[shard]),
            shard_id=jnp.int32(shard),
            all_labels=jnp.asarray(pg.all_labels),
        )

    # -------------------------------------------------- cached executables
    def _match_fn(self, spec: STwigSpec):
        kern = self.kernels
        return self.cache.get(
            ("match", spec, kern.name),
            lambda: jax.jit(
                functools.partial(match_stwig_shard, spec=spec, kernels=kern)
            ),
        )

    def _join_fn(
        self, schema_a, schema_b, out_cap: int, dup_cap: int,
        a_cap: int, b_cap: int,
    ):
        """Returns (jitted join fn, merged schema). The schema is static — it
        must not pass through jit. ``a_cap``/``b_cap`` are the operand table
        capacities: they shape the traced program (a blocked build side is
        narrower than a full table), so they belong to the logical key — one
        logical key, one trace."""
        kern = self.kernels

        def build():
            merged, _ = schema_a.merge(schema_b)
            fn = jax.jit(
                lambda a, b: join_lib.sort_merge_join(
                    a,
                    b,
                    schema_a,
                    schema_b,
                    out_cap=out_cap,
                    dup_cap=dup_cap,
                    kernels=kern,
                )[0]
            )
            return fn, merged

        return self.cache.get(
            ("join", schema_a, schema_b, out_cap, dup_cap, a_cap, b_cap,
             kern.name),
            build,
        )

    # ------------------------------------------------------------------ API
    def plan(self, query: QueryGraph, **kw) -> QueryPlan:
        return make_plan(query, self.pg.freq, **kw)

    def match(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        *,
        adaptive: bool = True,
        max_retries: int = 6,
        guard: "QueryGuard | None" = None,
        retry_policy: "RetryPolicy | None" = None,
        **kw,
    ) -> MatchResult:
        """Match with adaptive capacity growth: if any block capacity
        overflows (paper §4.2: block sizes are set by available memory), the
        plan is re-made with doubled capacities and the query re-runs. When
        an explicit ``plan`` is given, escalation starts from that plan's
        caps (like `CompiledQuery.run`) instead of being disabled. With
        ``adaptive=False`` the first (possibly partial) result is returned
        with ``complete=False`` — the paper's first-K pipelined semantics.

        Escalation runs through `repro.runtime.resilience.adaptive_run`:
        ``guard`` bounds the query by deadline/memory budget at the retry
        boundaries, ``retry_policy`` adds jittered backoff and stops cap
        growth at the budgets.json byte ceiling — both optional, both
        defaulting to the historical behaviour (no deadline, checked-in
        ceiling)."""
        policy = retry_policy or RetryPolicy(max_retries=max_retries)
        plan0 = plan if plan is not None else self.plan(query, **kw)
        return adaptive_run(
            lambda: self._match_once(query, plan0),
            lambda caps: self._match_once(query, None, **caps),
            caps_from_plan(plan0, kw),
            n_qnodes=query.n_nodes,
            backend="local",
            policy=policy,
            guard=guard,
            adaptive=adaptive,
        )

    def match_stream(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        *,
        block_rows: int = 1024,
        **kw,
    ) -> Iterator[MatchPage]:
        """Pipelined first-K execution (paper §6.1) — thin wrapper over the
        shared streaming driver (`repro.core.stream.stream_blocks`), kept
        for direct (deprecated) engine use. See the driver for the block
        semantics; both the local and sharded engines stream through it."""
        yield from stream_blocks(self, query, plan, block_rows=block_rows, **kw)

    # -------------------------------------------------- streaming interface
    def _stream_setup(
        self, query: QueryGraph, plan: QueryPlan | None = None, **kw
    ) -> "_LocalStreamState":
        """Run exploration once and pick the blocked (first-in-join-order)
        table; everything the per-block join step needs is returned as one
        reusable state object."""
        plan = plan or self.plan(query, **kw)
        stats = MatchStats(backend="local")
        with stage(stats, "explore"):
            tables, schemas, explore_overflow = self._explore(plan, stats)
        if self.chaos is not None and self.chaos.forced_overflow():
            explore_overflow = True
        order = tuple(join_lib.select_join_order(schemas, stats.stwig_rows))
        # probe-side compaction: every block join re-probes the non-blocked
        # tables, and probe cost scales with their capacity, not their row
        # count — shrink them once here (setup is already host-synced)
        for idx in order[1:]:
            tables[idx] = join_lib.compact_table(tables[idx])
        first = tables[order[0]]
        return _LocalStreamState(
            plan=plan,
            stats=stats,
            tables=tables,
            schemas=schemas,
            order=order,
            explore_overflow=explore_overflow,
            cap=int(first.cols.shape[0]),
            # one host copy of the blocked table's validity: empty blocks are
            # then skipped without any per-block device round-trip
            valid_host=np.asarray(jax.device_get(first.valid)),
        )

    def _stream_block(
        self, state: "_LocalStreamState", lo: int, block_rows: int
    ) -> tuple[np.ndarray, bool]:
        """Join rows ``[lo, lo+block_rows)`` of the blocked table through the
        join chain and materialize the block's matches."""
        if not state.valid_host[lo : lo + block_rows].any():
            return np.zeros((0, state.plan.n_qnodes), np.int64), False
        if self.chaos is not None:
            d = self.chaos.block_delay()
            if d > 0:
                time.sleep(d)
        first = state.tables[state.order[0]]
        blk = join_lib.block_table(first, lo, block_rows)
        self.join_block_calls += 1
        state.stats.join_blocks += 1
        with stage(state.stats, "join"):
            acc, acc_schema = blk, state.schemas[state.order[0]]
            for idx in state.order[1:]:
                fn, merged = self._join_fn(
                    acc_schema,
                    state.schemas[idx],
                    state.plan.join_rows_cap,
                    state.plan.join_dup_cap,
                    int(acc.cols.shape[0]),
                    int(state.tables[idx].cols.shape[0]),
                )
                acc, acc_schema = fn(acc, state.tables[idx]), merged
        with stage(state.stats, "materialize"):
            rows = self._materialize(acc, acc_schema, max_matches=0)
        return rows, bool(jax.device_get(acc.overflow))

    # ------------------------------------------------------ execution phases
    def _explore(
        self, plan: QueryPlan, stats: MatchStats
    ) -> tuple[list[join_lib.JoinTable], list[join_lib.Schema], bool]:
        """STwig exploration in Algorithm-2 order → per-STwig join inputs."""
        n_bits = self.pg.n_total + 1
        bind = Bindings.fresh(plan.n_qnodes, n_bits)
        tables: list[join_lib.JoinTable] = []
        schemas: list[join_lib.Schema] = []
        overflow = False
        for spec in plan.specs:
            fn = self._match_fn(spec)
            round_tables: list[STwigTable] = []
            contrib = None
            r = 0
            while True:
                table, c = fn(self.g, bind, round_idx=jnp.int32(r))
                round_tables.append(table)
                cw = c.words
                contrib = cw if contrib is None else jnp.bitwise_or(contrib, cw)
                n_roots = int(table.n_roots)
                r += 1
                if r * spec.root_cap >= n_roots:
                    break
            bind = apply_binding_update(bind, spec, contrib)
            jt = _concat_tables(round_tables)
            tables.append(jt)
            schemas.append(
                join_lib.Schema(
                    qnodes=spec.qnodes,
                    qlabels=(spec.root_label,) + spec.child_labels,
                )
            )
            stats.stwig_rows.append(int(jt.n_rows))
            stats.stwig_roots.append(int(round_tables[0].n_roots))
            stats.rounds.append(r)
            overflow |= bool(jax.device_get(jt.overflow))
        return tables, schemas, overflow

    def _materialize(
        self, acc: join_lib.JoinTable, acc_schema: join_lib.Schema, max_matches: int
    ) -> np.ndarray:
        """Device join table → host rows of ORIGINAL ids in query-node order."""
        cols = np.asarray(jax.device_get(acc.cols))
        valid = np.asarray(jax.device_get(acc.valid))
        rows_new = cols[valid]
        if max_matches and rows_new.shape[0] > max_matches:
            rows_new = rows_new[:max_matches]
        perm = np.argsort(np.asarray(acc_schema.qnodes))
        rows_new = rows_new[:, perm]
        rows_old = np.where(
            rows_new < self.pg.n_total,
            self.pg.new_to_old[np.minimum(rows_new, self.pg.n_total - 1)],
            -1,
        )
        return rows_old.astype(np.int64)

    def _match_once(
        self,
        query: QueryGraph,
        plan: QueryPlan | None = None,
        retry_policy=None,  # fetch recovery is a sharded concern; accepted
        # so the facade drives both engines uniformly
        **kw,
    ) -> MatchResult:
        t0 = time.perf_counter()
        plan = plan or self.plan(query, **kw)
        stats = MatchStats(backend="local")
        with stage(stats, "explore"):
            tables, schemas, overflow = self._explore(plan, stats)
        if self.chaos is not None and self.chaos.forced_overflow():
            overflow = True

        # ---- join phase ----------------------------------------------------
        with stage(stats, "join"):
            order = join_lib.select_join_order(schemas, stats.stwig_rows)
            acc, acc_schema = tables[order[0]], schemas[order[0]]
            for idx in order[1:]:
                fn, merged = self._join_fn(
                    acc_schema, schemas[idx], plan.join_rows_cap,
                    plan.join_dup_cap,
                    int(acc.cols.shape[0]), int(tables[idx].cols.shape[0]),
                )
                acc, acc_schema = fn(acc, tables[idx]), merged
            overflow |= bool(jax.device_get(acc.overflow))

        # ---- materialize (original ids, query-node column order) ----------
        with stage(stats, "materialize"):
            rows_old = self._materialize(acc, acc_schema, plan.max_matches)
        stats.join_order = [tuple(schemas[i].qnodes) for i in order]
        stats.time_s = time.perf_counter() - t0
        stats.n_join_rows = int(acc.n_rows)
        stats.cache_hits = self.cache.hits
        stats.cache_misses = self.cache.misses
        return MatchResult(
            rows=rows_old,
            n_matches=int(rows_old.shape[0]),
            complete=not overflow,
            stats=stats,
        )
