"""Typed query results: `MatchResult`, structured `MatchStats`, `MatchPage`.

The engines used to report execution details in an untyped ``stats`` dict;
these dataclasses make the schema explicit. ``MatchStats`` still supports
``stats["key"]`` access as a deprecation bridge for pre-facade callers —
it now emits `DeprecationWarning` on every use.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.deprecation import warn_dict_stats_access


@dataclasses.dataclass
class MatchStats:
    """Execution statistics for one query run.

    Per-STwig lists are indexed in exploration (Algorithm 2) order.
    ``cache_hits``/``cache_misses`` are the owning executable cache's
    cumulative counters at the end of the run (0 when no cache is attached).
    """

    backend: str = "local"             # "local" | "sharded"
    time_s: float = 0.0
    retries: int = 0                   # adaptive capacity-growth re-runs
    # why a partial result is partial — a `repro.runtime.resilience
    # .DegradeReason` value string ("deadline" | "budget" |
    # "overflow-ceiling" | "shard-fault"); None for complete results and
    # for plain first-K truncation (adaptive=False is semantics, not
    # degradation)
    degrade_reason: str | None = None
    # wall seconds per execution stage ("explore", "fetch", "join",
    # "materialize"), accumulated across blocks on the streaming path
    stage_times: dict[str, float] = dataclasses.field(default_factory=dict)
    # per-shard health after chaos/fault handling: shard -> "ok" | "slow" |
    # "dead" | "recovered" | "truncated" (sharded backend only)
    shard_health: dict[int, str] = dataclasses.field(default_factory=dict)
    # the grow-able capacities the final (possibly escalated) plan ran at
    final_caps: dict[str, int] = dataclasses.field(default_factory=dict)
    # fetch attempts beyond the first while recovering from shard faults
    fetch_retries: int = 0
    # block-parameterized join steps this query executed on the streaming
    # path (0 on one-shot runs); per-query — the engines' cumulative
    # `join_block_calls` counters sum these across all streams. The query
    # server's scheduler accounts its join quanta with this field.
    join_blocks: int = 0
    rounds: list[int] = dataclasses.field(default_factory=list)
    stwig_rows: list[int] = dataclasses.field(default_factory=list)
    # matching roots per STwig; both backends populate it (sharded reports
    # the max over shards — the shard that drives the round count)
    stwig_roots: list[int] = dataclasses.field(default_factory=list)
    join_order: list[tuple[int, ...]] = dataclasses.field(default_factory=list)
    n_join_rows: int = 0
    n_shards: int = 1
    cache_hits: int = 0
    cache_misses: int = 0

    # -------- deprecation bridge: the old dict-style access keeps working,
    # but warns — `tests/test_api.py` pins the warning
    def __getitem__(self, key: str):
        warn_dict_stats_access(key)
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def get(self, key: str, default=None):
        warn_dict_stats_access(key)
        return getattr(self, key, default)


@dataclasses.dataclass
class MatchResult:
    rows: np.ndarray          # (n_matches, n_qnodes) ORIGINAL node ids
    n_matches: int
    complete: bool            # False if any capacity overflowed (partial set)
    stats: MatchStats

    @property
    def degrade_reason(self) -> str | None:
        """Typed reason this result is partial (None when complete or when
        partial is first-K semantics, not degradation)."""
        return self.stats.degrade_reason


@dataclasses.dataclass
class MatchPage:
    """One page of a streaming (first-K, pipelined) run."""

    rows: np.ndarray          # (n_rows, n_qnodes) ORIGINAL node ids
    index: int                # 0-based page number
    complete: bool            # False if this page's block overflowed a cap
    # the query-level stats object, shared by every page of one stream
    # (retries, final caps, stage times, shard health accumulate there)
    stats: "MatchStats | None" = None

    @property
    def n_rows(self) -> int:
        return int(self.rows.shape[0])
