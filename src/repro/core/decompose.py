"""Query decomposition + STwig order selection (paper §5.1-§5.2, Algorithm 2).

Minimum STwig cover ≡ minimum vertex cover (Theorem 1, NP-hard), so the paper
uses a revised 2-approximation (Theorem 2) whose edge selection is guided by

  * rule 1 — prefer edges touching nodes bound by already-emitted STwigs, so
    every non-first STwig's root is bound (exploration prunes via bindings);
  * rule 2 — prefer high-selectivity nodes, ranked by the f-value
    f(v) = deg(v) / freq(v.label).

This module is a faithful transcription of Algorithm 2, plus the metadata the
matcher needs downstream (which query nodes are bound before each STwig).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.query import QueryGraph, STwig


@dataclasses.dataclass
class Decomposition:
    stwigs: list[STwig]
    # bound_before[i] = set of query nodes bound by stwigs[0..i-1]
    bound_before: list[set[int]]

    def covers(self, q: QueryGraph) -> bool:
        cov: set[tuple[int, int]] = set()
        for t in self.stwigs:
            cov |= t.covered_edges()
        return cov == set(q.edges)

    def edge_disjoint(self) -> bool:
        seen: set[tuple[int, int]] = set()
        for t in self.stwigs:
            for e in t.covered_edges():
                if e in seen:
                    return False
                seen.add(e)
        return True


def f_values(q: QueryGraph, freq: np.ndarray) -> np.ndarray:
    """f(v) = deg(v)/freq(label(v)); freq from the data graph (§5.2)."""
    deg = q.degrees().astype(np.float64)
    fr = np.maximum(freq[np.asarray(q.labels)], 1).astype(np.float64)
    return deg / fr


def stwig_order_selection(q: QueryGraph, freq: np.ndarray) -> Decomposition:
    """Algorithm 2 (STwig-Order-Selection).

    Returns the ordered STwig list T. Deterministic tie-breaking: highest
    f-value sum, then lexicographic (v, u).
    """
    adj = {v: set(ws) for v, ws in enumerate(q.adjacency())}
    live_edges: set[tuple[int, int]] = set(q.edges)
    f = f_values(q, freq)

    S: set[int] = set()
    stwigs: list[STwig] = []
    bound_before: list[set[int]] = []
    bound: set[int] = set()

    def deg(v: int) -> int:
        return len(adj[v])

    def pick_edge() -> tuple[int, int]:
        # returns (v, u) where v is the (first) STwig root
        best = None
        best_key = None
        for a, b in live_edges:
            for v, u in ((a, b), (b, a)):
                if S and v not in S:
                    continue
                key = (f[v] + f[u], f[v], -v, -u)
                if best_key is None or key > best_key:
                    best_key, best = key, (v, u)
        if best is None:  # S nonempty but disconnected remainder: restart rule
            best = max(
                ((a, b) for a, b in live_edges),
                key=lambda e: (f[e[0]] + f[e[1]], -e[0], -e[1]),
            )
        return best

    def emit(root: int) -> None:
        children = sorted(adj[root])
        stwigs.append(STwig.of(q, root, children))
        bound_before.append(set(bound))
        bound.add(root)
        bound.update(children)
        S.update(children)
        for c in children:
            adj[c].discard(root)
            live_edges.discard((min(root, c), max(root, c)))
        adj[root] = set()

    while live_edges:
        v, u = pick_edge()
        emit(v)
        if deg(u) > 0:
            emit(u)
        # remove u, v and degree-0 nodes from S
        S.discard(u)
        S.discard(v)
        for w in list(S):
            if deg(w) == 0:
                S.discard(w)

    return Decomposition(stwigs=stwigs, bound_before=bound_before)


def head_stwig_selection(
    q: QueryGraph, dec: Decomposition
) -> tuple[int, np.ndarray]:
    """§5.3: choose head STwig minimizing d(s) = max_i d(r_s, r_i) over the
    query's shortest-path matrix; return (head index, per-STwig distances
    d(r_head, r_t)) used for load sets (Theorem 4)."""
    M = q.shortest_paths()
    roots = [t.root for t in dec.stwigs]
    d = np.array([max(M[r, r2] for r2 in roots) for r in roots])
    head = int(np.argmin(d))
    dists = np.array([M[roots[head], r] for r in roots], dtype=np.int32)
    return head, dists
