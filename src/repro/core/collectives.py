"""Collectives for the distributed matcher.

The paper's cluster exchanges are (i) binding-set unions across machines and
(ii) load-set-bounded fetches of remote STwig tables. On a TPU mesh these
become:

  * ``or_allreduce`` — recursive-doubling butterfly of bitwise-OR over packed
    binding bitsets (log2(S) ppermute rounds, each moving the full bitset;
    XLA has no OR all-reduce primitive). Falls back to all-gather+reduce for
    non-power-of-two axis sizes.
  * ``gather_load_set`` — the faithful load-set fetch: all-gather the table
    and mask rows from shards outside F_{k,t} (Theorem 4). With a random
    hash partition the cluster graph is complete and this IS the paper's
    communication pattern; ``gather_load_set_ring`` (perf variant) moves
    only distance-bounded hops on sparse cluster graphs.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size


def or_allreduce(words: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Bitwise-OR all-reduce across a mesh axis."""
    n = axis_size(axis_name)
    if n == 1:
        return words
    if n & (n - 1) == 0:
        k = 1
        while k < n:
            perm = [(i, i ^ k) for i in range(n)]
            words = words | lax.ppermute(words, axis_name, perm)
            k *= 2
        return words
    g = lax.all_gather(words, axis_name)
    out = g[0]
    for i in range(1, n):
        out = out | g[i]
    return out


def bool_allreduce_any(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    return lax.pmax(x.astype(jnp.int32), axis_name) > 0


def gather_load_set(
    cols: jnp.ndarray,
    valid: jnp.ndarray,
    load_row: jnp.ndarray,
    axis_name: str,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fetch remote STwig tables, keeping rows only from shards in this
    shard's load set. cols (cap, w), valid (cap,), load_row (S,) bool."""
    S = axis_size(axis_name)
    g_cols = lax.all_gather(cols, axis_name)          # (S, cap, w)
    g_valid = lax.all_gather(valid, axis_name)        # (S, cap)
    g_valid &= load_row[:, None]
    return g_cols.reshape(S * cols.shape[0], cols.shape[1]), g_valid.reshape(-1)


def fetch_load_set(
    cols: jnp.ndarray,
    valid: jnp.ndarray,
    load_row: jnp.ndarray,
    axis_name: str,
    *,
    ring_radius: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One STwig-table fetch bounded by this shard's load set (Theorem 4):
    the distance-bounded ring exchange when a radius is given (the engine
    verified applicability host-side), the faithful all-gather otherwise.
    Single dispatch point shared by the fused join and the per-block
    streaming gather step."""
    if ring_radius is not None:
        return gather_load_set_ring(cols, valid, load_row, axis_name, ring_radius)
    return gather_load_set(cols, valid, load_row, axis_name)


def gather_load_set_ring(
    cols: jnp.ndarray,
    valid: jnp.ndarray,
    load_row: jnp.ndarray,
    axis_name: str,
    max_dist: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Distance-bounded variant: ``2*max_dist`` ppermute hops instead of a
    full all-gather. Output capacity is (2*max_dist+1) * cap — communication
    and memory proportional to the load-set radius, not the cluster size.

    Only valid when the cluster graph is (a subgraph of) the shard ring —
    e.g. range partitioning of a graph with ring/band locality. The engine
    checks applicability host-side before selecting this path.
    """
    S = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    h = min(max_dist, (S - 1) // 2)
    outs_c = [cols]
    outs_v = [valid & load_row[idx]]
    fwd_c, fwd_v = cols, valid
    bwd_c, bwd_v = cols, valid
    up = [(i, (i + 1) % S) for i in range(S)]
    down = [(i, (i - 1) % S) for i in range(S)]
    for d in range(1, h + 1):
        fwd_c = lax.ppermute(fwd_c, axis_name, up)
        fwd_v = lax.ppermute(fwd_v, axis_name, up)
        src_f = (idx - d) % S
        outs_c.append(fwd_c)
        outs_v.append(fwd_v & load_row[src_f])
        bwd_c = lax.ppermute(bwd_c, axis_name, down)
        bwd_v = lax.ppermute(bwd_v, axis_name, down)
        src_b = (idx + d) % S
        outs_c.append(bwd_c)
        outs_v.append(bwd_v & load_row[src_b])
    return (
        jnp.concatenate(outs_c, axis=0),
        jnp.concatenate(outs_v, axis=0),
    )
