"""Deprecation plumbing for the pre-facade surface.

Two things are deprecated for real (not just in docstrings): constructing
`SubgraphMatcher` / `DistributedMatcher` directly instead of opening a
`repro.api.GraphSession`, and the dict-style access bridge on `MatchStats`
(``stats["time_s"]`` / ``stats.get("time_s")``). Both now emit
`DeprecationWarning`; `tests/test_api.py` pins that they fire.

The facade itself constructs the engines, so engine ``__init__`` cannot
warn unconditionally — `GraphSession.open` wraps its construction in
`facade_construction()`, which suppresses the warning for exactly that
scope (a context variable, so it nests and survives threads correctly).
"""
from __future__ import annotations

import contextlib
import contextvars
import warnings

_IN_FACADE = contextvars.ContextVar("repro_facade_construction", default=False)


@contextlib.contextmanager
def facade_construction():
    """Mark engine construction as facade-internal (no warning)."""
    token = _IN_FACADE.set(True)
    try:
        yield
    finally:
        _IN_FACADE.reset(token)


def warn_direct_construction(name: str) -> None:
    """Emit the direct-engine-construction `DeprecationWarning` unless the
    construction is happening inside `GraphSession.open`."""
    if _IN_FACADE.get():
        return
    warnings.warn(
        f"constructing {name} directly is deprecated — open a "
        "repro.api.GraphSession instead (it selects the backend, owns the "
        "executable cache, and exposes compile/run/stream/serve)",
        DeprecationWarning,
        stacklevel=3,
    )


def warn_dict_stats_access(key: str) -> None:
    """Emit the dict-style `MatchStats` access `DeprecationWarning`."""
    warnings.warn(
        f"dict-style MatchStats access (stats[{key!r}]) is deprecated — "
        f"use the typed attribute (stats.{key}) instead",
        DeprecationWarning,
        stacklevel=3,
    )
