"""Vectorized MatchSTwig (paper Algorithm 1) — the TPU-native exploration.

The paper's per-root loop

    for n in Index.getID(r):  c = Cloud.Load(n); filter children by label/binding

becomes one edge-parallel pass over the shard's CSR arrays:

  1. root candidates  = (label == r) ∧ binding-bit(root)           (node-parallel)
  2. child candidates = (label[dst] == l_i) ∧ binding-bit(dst)     (edge-parallel)
  3. per-root candidate lists via segment-rank compaction (scatter)
  4. STwig emission   = masked cross-product over per-root lists
  5. binding update   = scatter-OR into packed bitsets

Everything is fixed-capacity (see plan.py); the function reports exact counts
and overflow flags so the engine can run more rounds. Every dense inner op —
bitset membership, the fused step-2/3 filter + compaction
(`repro.kernels.stwig_expand` on the Pallas backend), binding builds — goes
through the `Kernels` backend passed in (`repro.core.backend`); the default
``"jnp"`` backend is the reference oracle and the portable path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.backend import Kernels, get_kernels, n_words
from repro.core.plan import STwigSpec


class ShardGraph(NamedTuple):
    """One shard's slice of the partitioned graph (all jnp arrays)."""

    labels: jnp.ndarray        # (cap,) int32, pad = n_labels
    indptr: jnp.ndarray        # (cap+1,) int32
    indices: jnp.ndarray       # (edge_cap,) int32 global ids, pad = n_total
    edge_src: jnp.ndarray      # (edge_cap,) int32 local rows, pad = cap
    n_local: jnp.ndarray       # () int32
    n_local_edges: jnp.ndarray  # () int32
    shard_id: jnp.ndarray      # () int32
    all_labels: jnp.ndarray    # (n_total+1,) int32 (replicated)

    @property
    def cap(self) -> int:
        return self.labels.shape[0]

    @property
    def edge_cap(self) -> int:
        return self.indices.shape[0]

    @property
    def n_total(self) -> int:
        return self.all_labels.shape[0] - 1


class STwigTable(NamedTuple):
    """Fixed-capacity STwig match table G(q_i) for one shard/round."""

    cols: jnp.ndarray     # (rows_cap, width) int32 global ids, pad = ghost
    valid: jnp.ndarray    # (rows_cap,) bool
    n_rows: jnp.ndarray   # () int32 exact count (may exceed rows_cap)
    n_roots: jnp.ndarray  # () int32 total matching roots on this shard
    overflow: jnp.ndarray  # () bool — any capacity exceeded this round


class Bindings(NamedTuple):
    """Packed binding bitsets H_x for every query node (replicated)."""

    words: jnp.ndarray  # (n_qnodes, n_words) uint32

    @staticmethod
    def fresh(n_qnodes: int, n_bits: int) -> "Bindings":
        return Bindings(jnp.zeros((n_qnodes, n_words(n_bits)), jnp.uint32))


def _exclusive_cumsum(m: jnp.ndarray) -> jnp.ndarray:
    c = jnp.cumsum(m.astype(jnp.int32))
    return c - m.astype(jnp.int32)


def match_stwig_shard(
    g: ShardGraph,
    bind: Bindings,
    spec: STwigSpec,
    round_idx: jnp.ndarray,
    kernels: Kernels | None = None,
) -> tuple[STwigTable, Bindings]:
    """Match one STwig on one shard (round ``round_idx`` of root chunks).

    Returns the local match table and *this shard's contribution* to the new
    bindings for the STwig's query nodes (caller OR-reduces across shards,
    then replaces rows of ``bind``). ``kernels`` selects the backend for the
    dense inner ops (default: the jnp reference set) and must be bound
    statically (e.g. via ``functools.partial``) before ``jit``.
    """
    kern = kernels if kernels is not None else get_kernels("jnp")
    cap, edge_cap = g.cap, g.edge_cap
    n_total = g.n_total
    k = spec.n_children
    C, R = spec.child_cap, spec.root_cap
    W = bind.words.shape[1]

    node_slot = jnp.arange(cap, dtype=jnp.int32)
    gid = g.shard_id.astype(jnp.int32) * cap + node_slot

    # ---- step 1: root candidate mask (node-parallel) ----------------------
    root_mask = (g.labels == spec.root_label) & (node_slot < g.n_local)
    if spec.root_bound:
        root_mask &= kern.bitset_lookup(bind.words[spec.root_qnode], gid)

    # ---- steps 2-3: per-child candidate filter + per-root compaction ------
    e_pos = jnp.arange(edge_cap, dtype=jnp.int32)
    e_valid = e_pos < g.n_local_edges
    root_ok_e = e_valid & jnp.take(root_mask, g.edge_src, mode="clip") & (
        g.edge_src < cap
    )
    dst_labels = jnp.take(g.all_labels, g.indices, mode="clip")
    # (cap+2,) CSR bounds: row r's edges at [indptr[r], indptr[r+1]), the
    # ghost row cap owning the pad tail [indptr[cap], edge_cap)
    indptr_pad = jnp.concatenate(
        [g.indptr, jnp.full((1,), np.int32(edge_cap), jnp.int32)]
    )

    if k > 0:
        words_k = jnp.stack([bind.words[q] for q in spec.child_qnodes])
        cand_k, cnt_k = kern.stwig_expand(
            words_k,
            g.indices,
            dst_labels,
            indptr_pad,
            root_ok_e,
            child_labels=spec.child_labels,
            child_bound=spec.child_bound,
            child_cap=C,
            cap=cap,
            n_total=n_total,
        )
        # per child: (cap+1, C) ghost-padded candidate ids / (cap,) counts
        cand = [cand_k[i] for i in range(k)]
        cnt = [cnt_k[i] for i in range(k)]
    else:  # pragma: no cover — STwigs always have ≥1 child
        cand, cnt = [], []

    # ---- prune roots missing required children ----------------------------
    for i in range(k):
        root_mask &= cnt[i] >= spec.child_need[i]

    n_roots = jnp.sum(root_mask, dtype=jnp.int32)

    # ---- select this round's chunk of roots --------------------------------
    rank = _exclusive_cumsum(root_mask)
    lo = round_idx.astype(jnp.int32) * R
    sel = root_mask & (rank >= lo) & (rank < lo + R)
    chunk_pos = jnp.where(sel, rank - lo, np.int32(R))
    roots_sel = jnp.full((R,), cap, dtype=jnp.int32)
    roots_sel = roots_sel.at[chunk_pos].set(node_slot, mode="drop")
    root_live = roots_sel < cap
    root_gid = jnp.where(
        root_live, g.shard_id.astype(jnp.int32) * cap + roots_sel, np.int32(n_total)
    )

    cand_sel = [jnp.take(cand[i], roots_sel, axis=0, mode="clip") for i in range(k)]
    cnt_pad = [jnp.concatenate([cnt[i], jnp.zeros((1,), jnp.int32)]) for i in range(k)]
    cnt_sel = [jnp.take(cnt_pad[i], roots_sel, mode="clip") for i in range(k)]

    # ---- step 4: masked cross-product emission -----------------------------
    if k > 0:
        grid = jnp.indices((C,) * k, dtype=jnp.int32).reshape(k, -1)  # (k, P)
        P = grid.shape[1]
        child_vals = [
            jnp.take_along_axis(cand_sel[i], grid[i][None, :], axis=1)
            for i in range(k)
        ]  # each (R, P)
        ok = root_live[:, None] & jnp.ones((R, P), bool)
        for i in range(k):
            ok &= grid[i][None, :] < cnt_sel[i][:, None]
        for i, j in spec.same_label_child_pairs:
            ok &= grid[i][None, :] != grid[j][None, :]
        for i in spec.root_label_child_positions:
            ok &= child_vals[i] != root_gid[:, None]
        flat_ok = ok.reshape(-1)
        rows = jnp.stack(
            [jnp.broadcast_to(root_gid[:, None], (R, P)).reshape(-1)]
            + [v.reshape(-1) for v in child_vals],
            axis=1,
        )  # (R*P, width)
    else:  # pragma: no cover — STwigs always have ≥1 child
        flat_ok = root_live
        rows = root_gid[:, None]

    n_rows = jnp.sum(flat_ok, dtype=jnp.int32)
    rk = _exclusive_cumsum(flat_ok)
    out_pos = jnp.where(flat_ok, rk, np.int32(spec.rows_cap))
    cols = jnp.full((spec.rows_cap, spec.width), n_total, dtype=jnp.int32)
    cols = cols.at[out_pos].set(rows, mode="drop")
    valid = jnp.zeros((spec.rows_cap,), bool).at[out_pos].set(
        flat_ok, mode="drop"
    )

    overflow = (n_rows > spec.rows_cap) | jnp.any(
        jnp.stack([jnp.max(cnt[i]) > C for i in range(k)])
        if k
        else jnp.zeros((1,), bool)
    )

    # ---- step 5: binding contributions (scatter-OR) ------------------------
    new_words = []
    for pos_, _q in enumerate(spec.qnodes):
        col = cols[:, pos_]
        new_words.append(kern.bitset_build(col, valid, W))
    contrib = jnp.stack(new_words)  # (width, W)

    table = STwigTable(
        cols=cols, valid=valid, n_rows=n_rows, n_roots=n_roots, overflow=overflow
    )
    return table, Bindings(contrib)


def apply_binding_update(
    bind: Bindings, spec: STwigSpec, contrib_words: jnp.ndarray
) -> Bindings:
    """Replace the binding rows of this STwig's query nodes with the (already
    cross-shard-reduced) contribution. Replacement is valid because emitted
    columns are always subsets of prior bindings for bound nodes (§4.2)."""
    words = bind.words
    for pos, q in enumerate(spec.qnodes):
        words = words.at[q].set(contrib_words[pos])
    return Bindings(words)
