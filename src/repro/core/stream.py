"""The shared streaming driver for BOTH backends (paper §6.1).

An engine exposes two methods: ``_stream_setup`` runs the once-per-query
work (exploration; on the sharded backend also the load-set-bounded fetch of
remote STwig tables) and returns a state object, and
``_stream_block(state, lo, B)`` joins only rows ``[lo, lo+B)`` of the
blocked table — per-pair jitted joins locally, one block-parameterized
shard_map call on the sharded backend. This single loop replaces the two
divergent ``match_stream`` implementations; abandoning the iterator early
leaves all remaining blocks' joins unexecuted on either backend.
(`repro.api.compiled` re-exports the driver and layers paging/limits on top.)

The two halves are also exposed separately: `open_stream` runs the setup
eagerly and returns an `OpenStream` whose ``blocks()`` iterator joins one
block per ``next()`` — the scheduler quantum the continuous-batching
`repro.runtime.server.QueryServer` interleaves across many in-flight
queries on one device. `stream_blocks` composes the two lazily (setup on
first ``next()``), preserving the original generator semantics.

The block boundary is also the stream's preemption point: a
`repro.runtime.resilience.QueryGuard` passed as ``guard`` is checked
before every block, and a tripped deadline ends the stream with one final
degraded page (``complete=False``, the reason in the shared stats) — the
pages already delivered stay valid, the remaining blocks are never joined.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.plan import QueryPlan, caps_from_plan
from repro.core.query import QueryGraph
from repro.core.result import MatchPage


@dataclasses.dataclass(eq=False)
class OpenStream:
    """A stream whose run-once half has already executed.

    ``state`` holds the engine's per-query stream state (tables on device,
    schemas, join order); ``blocks()`` joins lazily, one block per
    ``next()``. One `OpenStream` belongs to one query — the query server
    holds many of them open at once and round-robins their block joins.
    """

    engine: object
    query: QueryGraph
    state: object
    guard: object
    block_rows: int  # effective B (clamped to the blocked table's capacity)

    @property
    def stats(self):
        """The stream's shared `MatchStats` (every page carries it)."""
        return self.state.stats

    @property
    def plan(self) -> QueryPlan:
        return self.state.plan

    @property
    def n_blocks(self) -> int:
        """Upper bound on join quanta left in a full consumption."""
        return -(-self.state.cap // self.block_rows)

    def blocks(self) -> Iterator[MatchPage]:
        """Yield one `MatchPage` per non-empty block of the blocked table.

        Pages are disjoint and their union over all blocks equals a
        one-shot ``max_matches=0`` run: blocks partition the blocked
        table's rows and every join output row descends from exactly one
        of them (on the sharded backend the blocked table is the head
        STwig, which is never fetched remotely — Theorem 5 — so per-shard
        results stay disjoint too). Streaming is inherently first-K: there
        is no adaptive retry; a page whose block overflowed a capacity
        reports ``complete=False``.
        """
        state, stats, guard = self.state, self.state.stats, self.guard
        index = 0
        for lo in range(0, state.cap, self.block_rows):
            if guard is not None:
                reason = guard.check()
                if reason is not None:
                    if stats.degrade_reason is None:
                        stats.degrade_reason = str(reason)
                    yield MatchPage(
                        rows=np.zeros((0, state.plan.n_qnodes), np.int64),
                        index=index,
                        complete=False,
                        stats=stats,
                    )
                    return
            rows, block_overflow = self.engine._stream_block(
                state, lo, self.block_rows
            )
            faulted = stats.degrade_reason is not None
            if rows.shape[0] == 0 and not block_overflow:
                continue
            yield MatchPage(
                rows=rows,
                index=index,
                complete=not (
                    state.explore_overflow or block_overflow or faulted
                ),
                stats=stats,
            )
            index += 1
        if index == 0 and (
            state.explore_overflow or stats.degrade_reason is not None
        ):
            # exploration overflowed (or the fetch degraded) and no block
            # produced rows: without a page the incompleteness would be
            # invisible to the consumer
            yield MatchPage(
                rows=np.zeros((0, state.plan.n_qnodes), np.int64),
                index=0,
                complete=False,
                stats=stats,
            )


def open_stream(
    engine,
    query: QueryGraph,
    plan: QueryPlan | None = None,
    *,
    block_rows: int = 1024,
    guard=None,
    **engine_kw,
) -> OpenStream:
    """Run the once-per-query half NOW (guard arming, exploration, and on
    the sharded backend the Theorem-4 fetch) and return the open stream.

    Eager setup is what the query server's admission step needs: admitting
    a query costs its exploration quantum up front, then every subsequent
    quantum is one block join interleavable with other in-flight queries.
    ``guard.start()`` is idempotent, so a guard armed at submission keeps
    its original epoch — queue wait counts against the deadline.
    """
    if guard is not None:
        guard.start()
    state = engine._stream_setup(query, plan, **engine_kw)
    stats = state.stats
    stats.retries = 0
    caps = caps_from_plan(state.plan)
    stats.final_caps = {
        k: caps[k] for k in ("child_cap", "join_rows_cap", "join_dup_cap")
    }
    return OpenStream(
        engine=engine,
        query=query,
        state=state,
        guard=guard,
        block_rows=max(1, min(block_rows, state.cap)),
    )


def stream_blocks(
    engine,
    query: QueryGraph,
    plan: QueryPlan | None = None,
    *,
    block_rows: int = 1024,
    guard=None,
    **engine_kw,
) -> Iterator[MatchPage]:
    """`open_stream` + `OpenStream.blocks`, composed lazily: nothing (not
    even setup) runs until the first ``next()``, matching the historical
    generator semantics every non-server consumer relies on."""
    yield from open_stream(
        engine, query, plan, block_rows=block_rows, guard=guard, **engine_kw
    ).blocks()
