"""The shared streaming driver for BOTH backends (paper §6.1).

An engine exposes two methods: ``_stream_setup`` runs the once-per-query
work (exploration; on the sharded backend also the load-set-bounded fetch of
remote STwig tables) and returns a state object, and
``_stream_block(state, lo, B)`` joins only rows ``[lo, lo+B)`` of the
blocked table — per-pair jitted joins locally, one block-parameterized
shard_map call on the sharded backend. This single loop replaces the two
divergent ``match_stream`` implementations; abandoning the iterator early
leaves all remaining blocks' joins unexecuted on either backend.
(`repro.api.compiled` re-exports the driver and layers paging/limits on top.)

The block boundary is also the stream's preemption point: a
`repro.runtime.resilience.QueryGuard` passed as ``guard`` is checked
before every block, and a tripped deadline ends the stream with one final
degraded page (``complete=False``, the reason in the shared stats) — the
pages already delivered stay valid, the remaining blocks are never joined.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.core.plan import QueryPlan, caps_from_plan
from repro.core.query import QueryGraph
from repro.core.result import MatchPage


def stream_blocks(
    engine,
    query: QueryGraph,
    plan: QueryPlan | None = None,
    *,
    block_rows: int = 1024,
    guard=None,
    **engine_kw,
) -> Iterator[MatchPage]:
    """Yield one `MatchPage` per non-empty block of the blocked table.

    Pages are disjoint and their union over all blocks equals a one-shot
    ``max_matches=0`` run: blocks partition the blocked table's rows and
    every join output row descends from exactly one of them (on the sharded
    backend the blocked table is the head STwig, which is never fetched
    remotely — Theorem 5 — so per-shard results stay disjoint too).
    Streaming is inherently first-K: there is no adaptive retry; a page
    whose block overflowed a capacity reports ``complete=False``.

    Every page carries the stream's shared stats object: ``retries`` is 0
    (no adaptive retry on this path) and ``final_caps`` reports the caps
    the plan actually ran at — run/stream stats parity for consumers that
    switch between the two.
    """
    if guard is not None:
        guard.start()
    state = engine._stream_setup(query, plan, **engine_kw)
    stats = state.stats
    stats.retries = 0
    caps = caps_from_plan(state.plan)
    stats.final_caps = {
        k: caps[k] for k in ("child_cap", "join_rows_cap", "join_dup_cap")
    }
    B = max(1, min(block_rows, state.cap))
    index = 0
    for lo in range(0, state.cap, B):
        if guard is not None:
            reason = guard.check()
            if reason is not None:
                if stats.degrade_reason is None:
                    stats.degrade_reason = str(reason)
                yield MatchPage(
                    rows=np.zeros((0, state.plan.n_qnodes), np.int64),
                    index=index,
                    complete=False,
                    stats=stats,
                )
                return
        rows, block_overflow = engine._stream_block(state, lo, B)
        faulted = stats.degrade_reason is not None
        if rows.shape[0] == 0 and not block_overflow:
            continue
        yield MatchPage(
            rows=rows,
            index=index,
            complete=not (state.explore_overflow or block_overflow or faulted),
            stats=stats,
        )
        index += 1
    if index == 0 and (state.explore_overflow or stats.degrade_reason is not None):
        # exploration overflowed (or the fetch degraded) and no block
        # produced rows: without a page the incompleteness would be
        # invisible to the consumer
        yield MatchPage(
            rows=np.zeros((0, state.plan.n_qnodes), np.int64),
            index=0,
            complete=False,
            stats=stats,
        )
