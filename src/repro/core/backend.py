"""The kernel backend layer: one swappable op set under every engine.

Every dense inner step of the matcher — bitset pack/unpack/lookup/build,
the fused STwig expansion (candidate filter + per-root compaction), the
standalone candidate filter, and the sort-merge hash-join probe — is an op
on a `Kernels` object. Both engines (`repro.core.engine`,
`repro.core.dist`) call through whatever `Kernels` they were opened with,
and the choice participates in every `ExecutableCache` key, so one session
can compare backends without cache poisoning (DESIGN.md §3).

Registered backends:

  * ``"jnp"``              — pure-jnp reference ops (the portable path and
                             the oracle for everything else);
  * ``"pallas"``           — Pallas TPU kernels (`repro.kernels.bitset`,
                             `repro.kernels.stwig_expand`,
                             `repro.kernels.hash_join`);
  * ``"pallas-interpret"`` — the same kernels in interpret mode: runs on
                             CPU, used by the parity tests in CI;
  * ``"auto"``             — resolves to ``"pallas"`` on TPU, ``"jnp"``
                             elsewhere.

Making any step faster now means writing one kernel and registering it —
not re-plumbing two engines: subclass `Kernels` (override only the ops you
accelerate) and `register_backend` it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.bitset import ref as _bitset_ref
from repro.kernels.hash_join import ref as _join_ref
from repro.kernels.stwig_expand import ref as _expand_ref

WORD_BITS = _bitset_ref.WORD_BITS
n_words = _bitset_ref.n_words


# ------------------------------------------------------------- op contracts
@dataclasses.dataclass(frozen=True)
class OpContract:
    """Machine-checkable shape/dtype contract for one `Kernels` op.

    Declared next to the ops so `register_backend` picks every backend up
    automatically: `repro.analysis.staticcheck` abstractly traces
    ``getattr(kernels, op)(*make_args()...)`` on every registered backend and
    walks the jaxpr — output dtypes must equal ``out_dtypes``, no value in
    the trace may be 64-bit wide (ids stay int32, bitsets stay uint32 — the
    linear-space discipline ROADMAP item 2 rests on), and none of the
    `BANNED_PRIMITIVES` (host callbacks / device transfers) may appear.

    ``make_args`` returns ``(args, kwargs)`` of small *example* inputs at the
    declared dtypes; they are traced, never executed, so cost is nil. New
    kernels: declare a contract here (or pass ``contracts=`` to
    `register_backend`) and the checker enforces it on every backend.
    """

    op: str
    make_args: Callable[[], tuple[tuple, dict]]
    out_dtypes: tuple[str, ...]


def _ex(shape, dtype):
    return jnp.zeros(shape, dtype)


def _contract_bitset_pack():
    return (_ex((64,), jnp.bool_),), {}


def _contract_bitset_unpack():
    return (_ex((2,), jnp.uint32),), {}


def _contract_bitset_lookup():
    return (_ex((2,), jnp.uint32), _ex((8,), jnp.int32)), {}


def _contract_bitset_build():
    return (_ex((8,), jnp.int32), _ex((8,), jnp.bool_), 2), {}


def _contract_candidate_filter():
    return (
        _ex((2,), jnp.uint32),
        _ex((16,), jnp.int32),
        _ex((16,), jnp.int32),
        _ex((16,), jnp.bool_),
        1,
    ), {}


def _contract_stwig_expand():
    return (
        _ex((2, 2), jnp.uint32),   # words_k
        _ex((16,), jnp.int32),     # dst_ids
        _ex((16,), jnp.int32),     # dst_labels
        _ex((10,), jnp.int32),     # indptr (cap+2,)
        _ex((16,), jnp.bool_),     # root_ok
    ), dict(
        child_labels=(1, 2),
        child_bound=(True, False),
        child_cap=4,
        cap=8,
        n_total=63,
    )


def _contract_hash_join_probe():
    return (
        _ex((16,), jnp.uint32),    # ka_sorted
        _ex((16, 2), jnp.int32),   # a_keys
        _ex((16,), jnp.bool_),     # a_valid
        _ex((8,), jnp.uint32),     # kb
        _ex((8, 2), jnp.int32),    # b_keys
        _ex((8,), jnp.bool_),      # b_valid
    ), dict(dup_cap=4)


def _contract_cin_layer():
    return (
        _ex((2, 3, 4), jnp.float32),   # xk
        _ex((2, 2, 4), jnp.float32),   # x0
        _ex((6, 3), jnp.float32),      # w
    ), {}


OP_CONTRACTS: tuple[OpContract, ...] = (
    OpContract("bitset_pack", _contract_bitset_pack, ("uint32",)),
    OpContract("bitset_unpack", _contract_bitset_unpack, ("bool",)),
    OpContract("bitset_lookup", _contract_bitset_lookup, ("bool",)),
    OpContract("bitset_build", _contract_bitset_build, ("uint32",)),
    OpContract("candidate_filter", _contract_candidate_filter, ("bool",)),
    OpContract("stwig_expand", _contract_stwig_expand, ("int32", "int32")),
    OpContract("hash_join_probe", _contract_hash_join_probe, ("bool", "int32")),
    OpContract("cin_layer", _contract_cin_layer, ("float32",)),
)


class Kernels:
    """The op interface engines program against. The base class IS the jnp
    reference implementation; accelerated backends override per op.

    All ops are shape-polymorphic pure functions safe under ``jit``,
    ``vmap`` and ``shard_map``; static configuration (labels, capacities)
    is keyword-only so engines can close over it at trace time.
    """

    name = "jnp"

    # ---------------------------------------------------- packed bitsets
    def bitset_pack(self, mask: jnp.ndarray) -> jnp.ndarray:
        """(n,) bool (n % 32 == 0) → (n/32,) uint32 packed words."""
        return _bitset_ref.pack_reference(mask)

    def bitset_unpack(self, words: jnp.ndarray) -> jnp.ndarray:
        """(W,) uint32 → (W*32,) bool."""
        return _bitset_ref.unpack_reference(words)

    def bitset_lookup(self, words: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
        """Membership test; negative / out-of-range ids are ``False``."""
        return _bitset_ref.lookup_reference(words, ids)

    def bitset_build(
        self, ids: jnp.ndarray, valid: jnp.ndarray, nwords: int
    ) -> jnp.ndarray:
        """Packed bitset from (possibly duplicated) masked ids."""
        return _bitset_ref.build_reference(ids, valid, nwords)

    # ------------------------------------------------------- exploration
    def candidate_filter(
        self, words, dst_ids, dst_labels, root_ok, child_label: int
    ) -> jnp.ndarray:
        """Fused MatchSTwig step-2 filter for ONE child label."""
        return _bitset_ref.candidate_filter_reference(
            words, dst_ids, dst_labels, root_ok, child_label
        )

    def stwig_expand(
        self,
        words_k,
        dst_ids,
        dst_labels,
        indptr,
        root_ok,
        *,
        child_labels: tuple[int, ...],
        child_bound: tuple[bool, ...],
        child_cap: int,
        cap: int,
        n_total: int,
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Fused steps 2-3: per-child filter + per-root compaction into
        candidate lists ``(k, cap+1, C)`` with exact counts ``(k, cap)``.
        ``indptr`` is the ``(cap+2,)`` CSR bounds array (edges grouped by
        root, ghost row ``cap`` owning the pad tail up to ``E``)."""
        return _expand_ref.stwig_expand_reference(
            words_k,
            dst_ids,
            dst_labels,
            indptr,
            root_ok,
            child_labels=child_labels,
            child_bound=child_bound,
            child_cap=child_cap,
            cap=cap,
            n_total=n_total,
        )

    # -------------------------------------------------------------- join
    def hash_join_probe(
        self, ka_sorted, a_keys, a_valid, kb, b_keys, b_valid, *, dup_cap: int
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Sorted windowed probe with exact-key verification: ``hit`` and
        sorted-side row indices, both ``(capB, dup_cap)``."""
        return _join_ref.probe_reference(
            ka_sorted, a_keys, a_valid, kb, b_keys, b_valid, dup_cap=dup_cap
        )

    # ---------------------------------------------------------- signatures
    def cin_layer(self, xk, x0, w) -> jnp.ndarray:
        """One CIN layer (compressed interaction): ``(B, H, d) × (B, m, d)
        × (H·m, H') → (B, H', d)`` — the contraction behind ROADMAP item
        3's learned neighborhood-signature filter."""
        from repro.kernels.cin.ref import cin_layer_reference

        return cin_layer_reference(xk, x0, w)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Kernels {self.name!r}>"


class PallasKernels(Kernels):
    """The Pallas TPU kernel set. ``interpret=True`` runs the same kernels
    through the Pallas interpreter (works on CPU — that is what CI's parity
    tests use); ``interpret=False`` compiles them with Mosaic on TPU."""

    def __init__(self, *, interpret: bool = False):
        self.interpret = interpret
        self.name = "pallas-interpret" if interpret else "pallas"

    def bitset_pack(self, mask):
        from repro.kernels.bitset import bitset_pack

        return bitset_pack(mask, interpret=self.interpret)

    def bitset_unpack(self, words):
        from repro.kernels.bitset import bitset_unpack

        return bitset_unpack(words, interpret=self.interpret)

    def bitset_lookup(self, words, ids):
        from repro.kernels.bitset import bitset_lookup

        return bitset_lookup(words, ids, interpret=self.interpret)

    def bitset_build(self, ids, valid, nwords):
        # scatter stays in XLA (no scatter-OR on TPU vector units); the
        # 32-lane pack runs in-kernel
        from repro.kernels.bitset import bitset_pack

        n_bits = nwords * WORD_BITS
        idx = jnp.where(valid, ids, np.int32(n_bits))
        bits = jnp.zeros((n_bits,), jnp.bool_).at[idx].set(True, mode="drop")
        return bitset_pack(bits, interpret=self.interpret)

    def candidate_filter(self, words, dst_ids, dst_labels, root_ok, child_label):
        from repro.kernels.bitset import candidate_filter

        return candidate_filter(
            words,
            dst_ids,
            dst_labels,
            root_ok,
            child_label,
            interpret=self.interpret,
        )

    def stwig_expand(self, *args, **kw):
        # full submodule path: the package attribute of the same name is
        # shadowed by the submodule if anyone imported it directly first
        from repro.kernels.stwig_expand.stwig_expand import stwig_expand

        return stwig_expand(*args, interpret=self.interpret, **kw)

    def hash_join_probe(self, *args, **kw):
        from repro.kernels.hash_join.hash_join import hash_join_probe

        return hash_join_probe(*args, interpret=self.interpret, **kw)

    def cin_layer(self, xk, x0, w):
        from repro.kernels.cin.cin import cin_layer

        return cin_layer(xk, x0, w, interpret=self.interpret)


# ------------------------------------------------------------------ registry
_REGISTRY: dict[str, Callable[[], Kernels]] = {}
_INSTANCES: dict[str, Kernels] = {}
_CONTRACTS: dict[str, tuple[OpContract, ...]] = {}

KERNEL_BACKENDS = ("auto", "jnp", "pallas", "pallas-interpret")


def register_backend(
    name: str,
    factory: Callable[[], Kernels],
    *,
    contracts: tuple[OpContract, ...] = OP_CONTRACTS,
) -> None:
    """Register a kernel backend under ``name`` (factory called lazily,
    once). Third-party backends can register here and be selected by name
    through `GraphSession.open(kernels=...)`.

    Every registered backend is bound to a tuple of `OpContract`s (default:
    the canonical `OP_CONTRACTS`) that `repro.analysis.staticcheck` enforces
    by abstract tracing — a backend that adds ops should pass an extended
    tuple so the new ops are checked too."""
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)
    _CONTRACTS[name] = contracts


def op_contracts(name: str) -> tuple[OpContract, ...]:
    """The contract set `register_backend` bound to backend ``name``."""
    return _CONTRACTS.get(name, OP_CONTRACTS)


def available_backends() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def get_kernels(name: str) -> Kernels:
    """The (singleton) `Kernels` registered under ``name``."""
    try:
        inst = _INSTANCES[name]
    except KeyError:
        try:
            factory = _REGISTRY[name]
        except KeyError:
            raise ValueError(
                f"unknown kernel backend {name!r}; registered: "
                f"{available_backends()}"
            ) from None
        inst = _INSTANCES[name] = factory()
    return inst


def resolve_kernels(spec: "str | Kernels | None" = None) -> Kernels:
    """Normalize a user-facing kernels spec: a `Kernels` instance passes
    through, ``None`` means ``"auto"``, and ``"auto"`` picks Pallas on TPU
    and jnp elsewhere (interpret mode is never auto-selected — it is a
    testing backend)."""
    if isinstance(spec, Kernels):
        return spec
    name = spec or "auto"
    if name == "auto":
        name = "pallas" if jax.default_backend() == "tpu" else "jnp"
    return get_kernels(name)


register_backend("jnp", Kernels)
register_backend("pallas", PallasKernels)
register_backend("pallas-interpret", lambda: PallasKernels(interpret=True))
