"""Query graphs and STwigs (paper §2.1, §4.1).

A subgraph query q = (V_q, E_q, T_q). Query nodes are integers 0..n-1 with
integer labels into the data graph's label alphabet. Unlike the paper's
presentation (which assumes uniquely-labeled query nodes for exposition), we
carry query-node ids everywhere, so duplicate labels are fully supported.

An STwig is a two-level tree q_i = (root, children): the *basic unit of graph
access* (§4.1).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class QueryGraph:
    n_nodes: int
    labels: tuple[int, ...]              # per query node
    edges: tuple[tuple[int, int], ...]   # undirected, u < v canonical

    @staticmethod
    def build(labels: list[int], edges: list[tuple[int, int]]) -> "QueryGraph":
        canon = sorted({(min(u, v), max(u, v)) for u, v in edges if u != v})
        return QueryGraph(
            n_nodes=len(labels), labels=tuple(labels), edges=tuple(canon)
        )

    def adjacency(self) -> list[set[int]]:
        adj: list[set[int]] = [set() for _ in range(self.n_nodes)]
        for u, v in self.edges:
            adj[u].add(v)
            adj[v].add(u)
        return adj

    def degrees(self) -> np.ndarray:
        deg = np.zeros(self.n_nodes, dtype=np.int64)
        for u, v in self.edges:
            deg[u] += 1
            deg[v] += 1
        return deg

    def shortest_paths(self) -> np.ndarray:
        """All-pairs shortest path lengths via Floyd-Warshall (§5.3: head
        STwig selection computes the matrix M). Queries are tiny (≤ ~32
        nodes) so O(n^3) host-side is free."""
        n = self.n_nodes
        INF = n + 1
        M = np.full((n, n), INF, dtype=np.int32)
        np.fill_diagonal(M, 0)
        for u, v in self.edges:
            M[u, v] = M[v, u] = 1
        for k in range(n):
            M = np.minimum(M, M[:, k : k + 1] + M[k : k + 1, :])
        return M

    def label_pairs(self) -> list[tuple[int, int]]:
        """Label pairs of query edges — drives the cluster graph (§5.3)."""
        return [(self.labels[u], self.labels[v]) for u, v in self.edges]

    def is_connected(self) -> bool:
        if self.n_nodes == 0:
            return True
        adj = self.adjacency()
        seen = {0}
        stack = [0]
        while stack:
            for w in adj[stack.pop()]:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        return len(seen) == self.n_nodes


@dataclasses.dataclass(frozen=True)
class STwig:
    """Two-level tree: root query-node + child query-nodes (§4.1)."""

    root: int
    children: tuple[int, ...]
    root_label: int
    child_labels: tuple[int, ...]

    @staticmethod
    def of(q: QueryGraph, root: int, children: list[int]) -> "STwig":
        return STwig(
            root=root,
            children=tuple(children),
            root_label=q.labels[root],
            child_labels=tuple(q.labels[c] for c in children),
        )

    @property
    def qnodes(self) -> tuple[int, ...]:
        return (self.root,) + self.children

    @property
    def width(self) -> int:
        return 1 + len(self.children)

    def covered_edges(self) -> set[tuple[int, int]]:
        return {(min(self.root, c), max(self.root, c)) for c in self.children}
