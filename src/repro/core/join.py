"""Join phase (paper §4.2 step 3): join-order selection + pipelined joins.

Intermediate STwig tables are joined on their shared query nodes. We use a
sort-merge join (TPU-friendly: one sort + lower-bound + windowed probe)
with static capacities. The probe — lower bound, window expansion, and
exact-key verification — is a `Kernels` op (`repro.core.backend`):
`repro.kernels.hash_join` is the Pallas implementation and
`repro.kernels.hash_join.ref` the jnp reference this module defaults to.

Two of the paper's optimizations appear here:
  * join order selection — greedy smallest-intermediate-first over runtime
    row counts (the paper applies a sample-based cost model [14]; our counts
    are exact since every table reports `n_rows`);
  * block-based pipelined join — the engine feeds the first table in blocks
    and stops once `max_matches` results are produced (§6.1 runs terminate
    after 1024 matches).

Rows are *subgraph-isomorphism* embeddings: any two query nodes with equal
labels must map to distinct data nodes; the filter runs incrementally at
every join (different-label pairs are distinct for free).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.backend import Kernels, get_kernels


class JoinTable(NamedTuple):
    cols: jnp.ndarray    # (cap, width) int32 global ids (ghost-padded)
    valid: jnp.ndarray   # (cap,) bool
    n_rows: jnp.ndarray  # () int32 exact (pre-truncation) count
    overflow: jnp.ndarray  # () bool


@dataclasses.dataclass(frozen=True)
class Schema:
    qnodes: tuple[int, ...]
    qlabels: tuple[int, ...]  # labels of those query nodes

    def merge(self, other: "Schema") -> tuple["Schema", tuple[int, ...]]:
        shared = tuple(q for q in other.qnodes if q in self.qnodes)
        extra = tuple(
            (q, l)
            for q, l in zip(other.qnodes, other.qlabels)
            if q not in self.qnodes
        )
        merged = Schema(
            self.qnodes + tuple(q for q, _ in extra),
            self.qlabels + tuple(l for _, l in extra),
        )
        return merged, shared


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 finalizer (uint32)."""
    x = x.astype(jnp.uint32)
    x ^= x >> 16
    x *= jnp.uint32(0x85EBCA6B)
    x ^= x >> 13
    x *= jnp.uint32(0xC2B2AE35)
    x ^= x >> 16
    return x


def _combine_keys(cols: jnp.ndarray, positions: tuple[int, ...]) -> jnp.ndarray:
    """Mix the key columns into one uint32 sort key. Collisions are possible
    (they only cost probe-window slots: exact column equality is always
    verified at probe time)."""
    k = jnp.zeros(cols.shape[0], dtype=jnp.uint32)
    for p in positions:
        k = _mix32(k ^ _mix32(cols[:, p].astype(jnp.uint32)))
        k = k * jnp.uint32(5) + jnp.uint32(0xE6546B64)
    return k


def sort_merge_join(
    a: JoinTable,
    b: JoinTable,
    schema_a: Schema,
    schema_b: Schema,
    *,
    out_cap: int,
    dup_cap: int,
    kernels: Kernels | None = None,
) -> tuple[JoinTable, Schema]:
    """R_a ⋈ R_b on shared query nodes; output capacity ``out_cap``;
    at most ``dup_cap`` equal-key rows on the build (a) side per probe.
    ``kernels`` selects the probe backend (default: jnp reference) and must
    be bound statically before ``jit``."""
    kern = kernels if kernels is not None else get_kernels("jnp")
    merged_schema, shared = schema_a.merge(schema_b)
    assert shared, "join between disconnected tables"
    pos_a = tuple(schema_a.qnodes.index(q) for q in shared)
    pos_b = tuple(schema_b.qnodes.index(q) for q in shared)

    BIG = jnp.uint32(0xFFFFFFFF)
    key_a = jnp.where(a.valid, _combine_keys(a.cols, pos_a), BIG)
    key_b = _combine_keys(b.cols, pos_b)
    # sort with an int32 payload rather than argsort: argsort's permutation
    # is int64 under x64 and would widen every downstream gather
    iota = jnp.arange(key_a.shape[0], dtype=jnp.int32)
    ka, order = lax.sort((key_a, iota), num_keys=1)
    a_valid_s = a.valid[order]

    # build-side duplicate-run overflow detection
    run_start = jnp.concatenate(
        [jnp.ones((1,), bool), ka[1:] != ka[:-1]]
    ) | ~a_valid_s
    run_id = jnp.cumsum(run_start.astype(jnp.int32)) - 1
    run_len = jnp.zeros(ka.shape[0], jnp.int32).at[run_id].add(1)
    dup_overflow = jnp.max(jnp.where(a_valid_s, run_len[run_id], np.int32(0))) > dup_cap

    # windowed probe with exact-key verification — one fused backend op
    W = dup_cap
    key_pos_a = jnp.asarray(pos_a, jnp.int32)
    key_pos_b = jnp.asarray(pos_b, jnp.int32)
    a_keys_s = a.cols[order][:, key_pos_a]     # (na, nk) sorted key columns
    b_keys = b.cols[:, key_pos_b]              # (nb, nk)
    hit, probe_c = kern.hash_join_probe(
        ka, a_keys_s, a_valid_s, key_b, b_keys, b.valid, dup_cap=W
    )
    a_rows = order[probe_c]

    # merged row values: all of a's columns + b's extra columns
    extra_pos_b = tuple(
        i for i, q in enumerate(schema_b.qnodes) if q not in schema_a.qnodes
    )
    nb = b.cols.shape[0]
    flat_hit = hit.reshape(-1)
    a_rows_f = a_rows.reshape(-1)
    b_rows_f = jnp.broadcast_to(
        jnp.arange(nb, dtype=jnp.int32)[:, None], (nb, W)
    ).reshape(-1)
    merged_cols = jnp.concatenate(
        [a.cols[a_rows_f]]
        + [jnp.take(b.cols[:, p], b_rows_f)[:, None] for p in extra_pos_b],
        axis=1,
    )  # (nb*W, w_merged)

    # isomorphism (injectivity) filter on equal-label column pairs
    labs = merged_schema.qlabels
    wm = len(merged_schema.qnodes)
    for i in range(wm):
        for j in range(i + 1, wm):
            if labs[i] == labs[j]:
                flat_hit &= merged_cols[:, i] != merged_cols[:, j]

    n_rows = jnp.sum(flat_hit, dtype=jnp.int32)
    rk = jnp.cumsum(flat_hit.astype(jnp.int32)) - flat_hit.astype(jnp.int32)
    out_pos = jnp.where(flat_hit, rk, np.int32(out_cap))
    ghost = jnp.max(a.cols)  # any value; rows are masked by `valid`
    cols = jnp.full((out_cap, wm), ghost, dtype=jnp.int32)
    cols = cols.at[out_pos].set(merged_cols, mode="drop")
    valid = jnp.zeros((out_cap,), bool).at[out_pos].set(flat_hit, mode="drop")
    overflow = (n_rows > out_cap) | dup_overflow | a.overflow | b.overflow

    return (
        JoinTable(cols=cols, valid=valid, n_rows=n_rows, overflow=overflow),
        merged_schema,
    )


def block_table(table: JoinTable, lo, block_rows: int) -> JoinTable:
    """Rows ``[lo, lo+block_rows)`` of a join table as a fixed-shape block.

    ``lo`` may be a traced scalar: rows are read through a clamped gather so
    one trace serves every block of a given size, and indices past the
    table's capacity are masked invalid (the clamp would otherwise re-read
    the last row and duplicate matches). This is the build side of the
    paper's block-based pipelined join (§4.2 step 3 / §6.1): blocks
    partition the table's valid rows, and every join output row descends
    from exactly one build-side row, so per-block results are disjoint and
    their union equals the unblocked join.
    """
    cap = int(table.cols.shape[0])
    idx = jnp.asarray(lo, jnp.int32) + jnp.arange(block_rows, dtype=jnp.int32)
    safe = jnp.minimum(idx, cap - 1)
    valid = table.valid[safe] & (idx < cap)
    return JoinTable(
        cols=table.cols[safe],
        valid=valid,
        n_rows=jnp.sum(valid, dtype=jnp.int32),
        overflow=jnp.bool_(False),
    )


def compact_table(table: JoinTable, min_cap: int = 64) -> JoinTable:
    """Squeeze a table's valid rows to the front and shrink its capacity to
    the smallest power of two that holds them all (host-side; costs one
    device round-trip, so callers must already be off the async path).

    STwig tables are allocated at worst-case capacity but are usually
    sparse, and the probe side of every join pays O(cap × dup_cap) in
    window expansion and scatter regardless of how many rows are real.
    The streaming path re-probes the same tables once per block, so the
    setup step compacts them once and every block join gets cheaper.
    Lossless by construction: the compact capacity covers every valid row
    and the exact-count/overflow flags are carried over unchanged. The
    one-shot path stays fully on device and keeps full-capacity tables.
    """
    cols = np.asarray(table.cols)
    valid = np.asarray(table.valid)
    keep = np.nonzero(valid)[0]
    cap = int(cols.shape[0])
    new_cap = min_cap
    while new_cap < len(keep):
        new_cap *= 2
    if new_cap >= cap:
        return table
    out_cols = np.zeros((new_cap, cols.shape[1]), cols.dtype)
    out_cols[: len(keep)] = cols[keep]
    out_valid = np.zeros((new_cap,), bool)
    out_valid[: len(keep)] = True
    return JoinTable(
        cols=jnp.asarray(out_cols),
        valid=jnp.asarray(out_valid),
        n_rows=table.n_rows,
        overflow=table.overflow,
    )


def select_join_order(
    schemas: list[Schema], counts: list[int], start: int | None = None
) -> list[int]:
    """Greedy smallest-intermediate-first join order (host-side).

    Start from the smallest table (or a forced start, e.g. a blocked first
    table in pipelined mode); repeatedly pick the connected table whose
    estimated output (count scaled by shared-key count) is smallest.
    """
    n = len(schemas)
    remaining = set(range(n))
    first = start if start is not None else min(remaining, key=lambda i: counts[i])
    order = [first]
    remaining.discard(first)
    joined = set(schemas[first].qnodes)
    while remaining:
        connected = [i for i in remaining if joined & set(schemas[i].qnodes)]
        pool = connected or list(remaining)
        # more shared keys → more selective; fewer rows → cheaper
        nxt = min(
            pool,
            key=lambda i: (-len(joined & set(schemas[i].qnodes)), counts[i]),
        )
        order.append(nxt)
        remaining.discard(nxt)
        joined |= set(schemas[nxt].qnodes)
    return order
