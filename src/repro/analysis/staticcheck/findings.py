"""Finding/rule plumbing shared by the three staticcheck passes.

A `Finding` names the rule that fired, where, and why; rules are registered
in a flat table so the CLI can list them and the fixture suite can assert
each one both fires on a planted violation and stays silent on the clean
tree. Suppression: an AST rule skips any source line carrying a
``# staticcheck: ignore[rule-id]`` comment (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Iterable

_IGNORE_RE = re.compile(r"#\s*staticcheck:\s*ignore\[([a-z0-9-]+(?:,\s*[a-z0-9-]+)*)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # file (or backend/op pseudo-path) the finding is in
    line: int          # 1-based; 0 when not line-addressable (traced passes)
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    layer: str         # which architectural layer the rule protects
    description: str


RULES: dict[str, Rule] = {}


def rule(id: str, layer: str, description: str) -> Rule:
    r = Rule(id, layer, description)
    RULES[id] = r
    return r


def suppressed_lines(source: str) -> dict[int, set[str]]:
    """Map of 1-based line number → rule ids suppressed on that line."""
    out: dict[int, set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _IGNORE_RE.search(text)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",")}
    return out


def is_suppressed(sup: dict[int, set[str]], line: int, rule_id: str) -> bool:
    return rule_id in sup.get(line, ())


def report_json(findings: Iterable[Finding], extras: dict | None = None) -> str:
    """`extras` merges additional report sections (e.g. the collective
    sequences and cost report) into the JSON document; reserved keys
    cannot be overridden."""
    fs = list(findings)
    doc = dict(extras or {})
    doc.update(
        {
            "n_findings": len(fs),
            "rules": {
                rid: dataclasses.asdict(r) for rid, r in sorted(RULES.items())
            },
            "findings": [f.to_dict() for f in fs],
        }
    )
    return json.dumps(doc, indent=2)
