"""Engine entry-point probe (staticcheck passes a+b on the live engines).

Opens a tiny `GraphSession` per (engine backend × kernel backend)
combination, installs the `ExecutableCache` recorder, and drives the real
entry points — ``compile``, ``run`` (block-join steps), ``stream`` plus a
re-stream (Theorem-4 gather + block-join steps on the sharded engine).
Every executable the cache built is then re-traced with its recorded
concrete arguments and its jaxpr walked with the same rules as the kernel
op contracts (`contracts.check_jaxpr`).

Retrace rule: after run + stream + re-stream, no logical cache key may have
traced twice (`duplicate_traces`) and no cached jitted executable may hold
more than one trace under its single key (`retraced_executables` — the
silent variant where a static argument escaped the cache key; the AST-level
companion is `cachekeys.check_cache_keys`).

The probe executes real work, so it costs a few seconds per combination —
the graph is ~100 nodes and every capacity is tiny.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.analysis.staticcheck.contracts import check_jaxpr
from repro.analysis.staticcheck.findings import Finding, rule

rule("retrace", "engine",
     "a logical executable-cache key traced more than once across "
     "run/stream/re-stream (or a jitted executable silently re-traced "
     "under one key)")

ENGINE_BACKENDS = ("local", "sharded")
KERNEL_BACKENDS = ("jnp", "pallas-interpret")


@dataclasses.dataclass
class EntryTrace:
    """One cached executable, re-traced: the raw material the downstream
    collective-safety and cost-model passes analyze."""

    key: tuple          # the ExecutableCache key
    target: str         # engine:<backend>:<kernels>:<key head>
    backend: str        # engine backend ("local" | "sharded")
    kernels: str        # kernel backend name
    jaxpr: object       # ClosedJaxpr from jax.make_jaxpr


def _tiny_graph(scale: int = 1):
    from repro.graphstore import generators

    # scale multiplies nodes AND edges so density (and therefore caps
    # derived from plans) grows linearly — the cost pass compares peak
    # bytes across two scales to assert the paper's linear-space bound
    return generators.rmat(120 * scale, 420 * scale, 4, seed=3,
                           symmetrize=True)


def _probe_query():
    from repro.core.query import QueryGraph

    # a labeled 4-path decomposes into ≥2 STwigs, so the probe exercises
    # match, join (block-join steps) and the sharded gather path
    return QueryGraph.build([0, 1, 2, 3], [(0, 1), (1, 2), (2, 3)])


def _key_head(key) -> str:
    if isinstance(key, tuple) and key and isinstance(key[0], str):
        return key[0]
    return type(key).__name__


def probe_traces(
    backend: str, kernels: str, *, scale: int = 1
) -> "tuple[list[Finding], list[EntryTrace]]":
    """Drive one engine/kernels combination end to end, check every
    executable it built (contracts + retrace rules), and return the
    re-traced jaxprs for the collective-safety and cost-model passes."""
    from repro.api.session import GraphSession

    findings: list[Finding] = []
    traces: list[EntryTrace] = []
    target = f"engine:{backend}:{kernels}"
    recorded: dict = {}

    def recorder(key, fn, args, kwargs):
        recorded.setdefault(key, (fn, args, kwargs))

    session = GraphSession.open(
        _tiny_graph(scale), backend=backend, kernels=kernels
    )
    try:
        session.cache.recorder = recorder
        compiled = session.compile(_probe_query(), max_matches=0)
        compiled.run(adaptive=False)
        for _ in compiled.stream(page_size=16):
            pass
        for _ in compiled.stream(page_size=16):  # re-stream: all cache hits
            pass

        for key in session.cache.duplicate_traces():
            findings.append(Finding(
                "retrace", f"{target}:{_key_head(key)}", 0,
                f"logical key traced more than once: {key!r}",
            ))
        for key, n in session.cache.retraced_executables():
            findings.append(Finding(
                "retrace", f"{target}:{_key_head(key)}", 0,
                f"executable re-traced {n}x under one cache key (a static "
                f"argument is missing from the key): {key!r}",
            ))

        for key, (fn, args, kwargs) in recorded.items():
            ktarget = f"{target}:{_key_head(key)}"
            try:
                jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
            except Exception as e:
                findings.append(Finding(
                    "jaxpr-out-dtype", ktarget, 0,
                    f"entry point failed to re-trace with its recorded "
                    f"arguments: {type(e).__name__}: {e}",
                ))
                continue
            findings.extend(check_jaxpr(jaxpr, ktarget))
            traces.append(EntryTrace(
                key=key, target=ktarget, backend=backend,
                kernels=kernels, jaxpr=jaxpr,
            ))
    finally:
        session.close()
    return findings, traces


def probe_engine(backend: str, kernels: str) -> list[Finding]:
    """Contract/retrace findings only (see `probe_traces`)."""
    findings, _ = probe_traces(backend, kernels)
    return findings


def check_engines(
    backends=ENGINE_BACKENDS, kernels=KERNEL_BACKENDS
) -> list[Finding]:
    findings, _ = check_engines_traces(backends, kernels)
    return findings


def check_engines_traces(
    backends=ENGINE_BACKENDS, kernels=KERNEL_BACKENDS, *, scale: int = 1
) -> "tuple[list[Finding], list[EntryTrace]]":
    findings: list[Finding] = []
    traces: list[EntryTrace] = []
    for b in backends:
        for k in kernels:
            fs, ts = probe_traces(b, k, scale=scale)
            findings.extend(fs)
            traces.extend(ts)
    return findings, traces
