"""repro.analysis.staticcheck — the repo's own static analyzer.

Five passes over the matcher (DESIGN.md §5 "Checked invariants"):

  a. jaxpr contract checker (`contracts`, `engines`): every registered
     `Kernels` op and every engine entry point abstractly traced and its
     jaxpr walked — output dtypes as declared, no 64-bit values, no host
     callbacks in hot paths;
  b. retrace detector (`engines`, plus `cachekeys` statically): one logical
     `ExecutableCache` key traces exactly once across run/stream/re-stream;
  c. architecture lint (`archlint`): AST rules keeping bit-twiddling,
     module-level jit state, engine construction, and stream consumers
     where DESIGN.md says they live;
  d. collective safety (`collective_safety`): every `shard_map` body the
     sharded engine traced — no collective under shard-divergent control
     flow, every `ppermute` a bijection over the mesh axis, axis names
     resolved, head-STwig tables never gathered (Theorem 5);
  e. static cost model (`costmodel`): per-executable peak resident bytes
     (liveness), FLOPs, and collective bytes against checked-in ceilings
     in `src/repro/analysis/budgets.json` — fail-closed on missing rows,
     linear-in-graph-size memory asserted across two probe scales.

Run as ``python -m repro.analysis.staticcheck [--json]`` (exit 1 on any
finding) or through the pytest suite (`tests/test_staticcheck.py`).
"""
from __future__ import annotations

import pathlib

from repro.analysis.staticcheck.findings import (  # noqa: F401
    Finding,
    Rule,
    RULES,
    report_json,
)

# the bigger of the two probe scales for the linear-memory assertion; the
# cost of the probe grows with it, the discrimination (linear vs quadratic
# ≈ scale vs scale²) too
MEMORY_SCALE = 4


def run_all(
    repo_root: "pathlib.Path | str | None" = None,
    *,
    engines: bool = True,
    kernel_backends=None,
    collectives: bool = True,
    costs: bool = True,
    reports: "dict | None" = None,
) -> "list[Finding]":
    """All passes; the one-call entry the CLI and the test suite share.

    The collective-safety and cost-model passes consume the jaxprs the
    engine probe records, so ``engines=False`` skips them too. Pass a dict
    as ``reports`` to receive the machine-readable side reports
    (``collectives``: per-shard_map collective sequences, ``cost_report``:
    per-executable estimates + per-target aggregates) — the CLI folds them
    into ``--json`` output.
    """
    from repro.analysis.staticcheck import archlint, cachekeys, contracts
    from repro.analysis.staticcheck import engines as engines_mod

    if repo_root is None:
        repo_root = pathlib.Path(__file__).resolve().parents[4]
    repo_root = pathlib.Path(repo_root)

    findings = list(contracts.check_kernel_contracts(kernel_backends))
    if engines:
        probe_kernels = kernel_backends or engines_mod.KERNEL_BACKENDS
        engine_findings, traces = engines_mod.check_engines_traces(
            kernels=probe_kernels,
        )
        findings.extend(engine_findings)
        if collectives:
            from repro.analysis.staticcheck import collective_safety

            shard_reports: list = []
            findings.extend(collective_safety.check_traces(
                traces, reports=shard_reports,
            ))
            if reports is not None:
                reports["collectives"] = [
                    r.to_dict() for r in shard_reports
                ]
        if costs:
            from repro.analysis.staticcheck import costmodel

            estimates = [
                costmodel.estimate(t.jaxpr, target=t.target) for t in traces
            ]
            findings.extend(costmodel.check_budgets(estimates))
            # linear-memory bound: re-probe a MEMORY_SCALE× graph on the
            # jnp kernels (pallas-interpret re-runs the same programs —
            # scaling it would only re-pay the slow interpreter)
            _, big_traces = engines_mod.check_engines_traces(
                kernels=("jnp",), scale=MEMORY_SCALE,
            )
            big = [
                costmodel.estimate(t.jaxpr, target=t.target)
                for t in big_traces
            ]
            budgets = costmodel.load_budgets()
            findings.extend(costmodel.check_linear_memory(
                estimates, big,
                size_ratio=float(MEMORY_SCALE),
                slack=float(budgets.get("linear_slack", 2.0)),
            ))
            if reports is not None:
                reports["cost_report"] = {
                    "executables": [e.to_dict() for e in estimates],
                    "aggregates": costmodel.aggregate(estimates),
                    "memory_scale": MEMORY_SCALE,
                    "aggregates_scaled": costmodel.aggregate(big),
                }
    findings.extend(cachekeys.check_cache_keys(repo_root))
    findings.extend(archlint.run(repo_root))
    return findings
