"""repro.analysis.staticcheck — the repo's own static analyzer.

Three passes over the matcher (DESIGN.md §5 "Checked invariants"):

  a. jaxpr contract checker (`contracts`, `engines`): every registered
     `Kernels` op and every engine entry point abstractly traced and its
     jaxpr walked — output dtypes as declared, no 64-bit values, no host
     callbacks in hot paths;
  b. retrace detector (`engines`, plus `cachekeys` statically): one logical
     `ExecutableCache` key traces exactly once across run/stream/re-stream;
  c. architecture lint (`archlint`): AST rules keeping bit-twiddling,
     module-level jit state, engine construction, and stream consumers
     where DESIGN.md says they live.

Run as ``python -m repro.analysis.staticcheck [--json]`` (exit 1 on any
finding) or through the pytest suite (`tests/test_staticcheck.py`).
"""
from __future__ import annotations

import pathlib

from repro.analysis.staticcheck.findings import (  # noqa: F401
    Finding,
    Rule,
    RULES,
    report_json,
)


def run_all(
    repo_root: "pathlib.Path | str | None" = None,
    *,
    engines: bool = True,
    kernel_backends=None,
) -> "list[Finding]":
    """All passes; the one-call entry the CLI and the test suite share."""
    from repro.analysis.staticcheck import archlint, cachekeys, contracts
    from repro.analysis.staticcheck import engines as engines_mod

    if repo_root is None:
        repo_root = pathlib.Path(__file__).resolve().parents[4]
    repo_root = pathlib.Path(repo_root)

    findings = list(contracts.check_kernel_contracts(kernel_backends))
    if engines:
        findings.extend(engines_mod.check_engines(
            kernels=kernel_backends or engines_mod.KERNEL_BACKENDS,
        ))
    findings.extend(cachekeys.check_cache_keys(repo_root))
    findings.extend(archlint.run(repo_root))
    return findings
