"""Collective-safety analysis (staticcheck pass d).

The sharded engine's correctness-critical surface is its collective
structure: the OR-allreduce butterfly, the Theorem-4 load-set fetches
(all-gather or the distance-bounded ppermute ring), and the Theorem-5
head-locality rule that keeps per-shard result pages disjoint. This pass
walks every sharded-engine jaxpr recorded by the `ExecutableCache.recorder`
probe, finds each `shard_map` equation, extracts its collective sequence,
and enforces four machine-checked invariants:

  * ``coll-divergent-control``   — no collective under shard-divergent
    control flow: a `cond`/`while` whose predicate depends on per-shard
    data (sharded inputs, `axis_index`) may take different branches / trip
    counts on different shards, and a collective inside it deadlocks the
    SPMD program (some shards enter the collective, others never do).
    Values produced by full-axis `psum`/`pmax`/`pmin`/`all_gather` are
    replicated and therefore convergent predicates.
  * ``coll-ppermute-bijection``  — every `ppermute` permutation is a
    bijection over the mesh axis: each shard sends exactly once and
    receives exactly once. The ring fetch in
    `repro.core.collectives.gather_load_set_ring` is the riskiest
    construction — a missing (src, dst) pair silently zero-fills a
    neighbour's STwig table instead of failing.
  * ``coll-axis-name``           — collective axis names resolve against
    the enclosing `shard_map` mesh AND against the engine's declared axis
    set (`repro.core.dist.AXIS`); a stray axis name is a latent trace
    error that only fires on a differently-shaped mesh.
  * ``coll-head-gather``         — Theorem 5 as a static invariant: the
    head-STwig table is never an operand of any gather collective
    (`all_gather` / `ppermute` / `all_to_all`). Head rows staying local is
    what makes per-shard pages provably disjoint; fetching the head
    remotely would re-introduce cross-shard duplicates. Head operands are
    identified positionally from the executable-cache key
    (`head_taints_for_key`) and taint-propagated through the body.

Everything here is jaxpr-walking — nothing executes, so the pass adds
milliseconds on top of the engine probe that recorded the traces.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from repro.analysis.staticcheck.findings import Finding, rule

rule("coll-divergent-control", "collectives",
     "collective nested under a cond/while whose predicate depends on "
     "per-shard data (static SPMD deadlock hazard)")
rule("coll-ppermute-bijection", "collectives",
     "ppermute permutation is not a bijection over the mesh axis")
rule("coll-axis-name", "collectives",
     "collective axis name absent from the enclosing shard_map mesh or "
     "from the engine's declared axis set")
rule("coll-head-gather", "collectives",
     "head-STwig table flows into a gather collective (Theorem 5: the "
     "head is never fetched remotely — that is what keeps per-shard "
     "pages disjoint)")

# Every cross-shard primitive we track. `psum`/`pmax`/`pmin` produce
# replicated (convergent) outputs over the full axis; gather-shaped ones
# move table data between shards.
REDUCE_COLLECTIVES = ("psum", "pmax", "pmin")
GATHER_COLLECTIVES = ("all_gather", "ppermute", "all_to_all")
COLLECTIVE_PRIMS = frozenset(REDUCE_COLLECTIVES + GATHER_COLLECTIVES + (
    "psum_invariant", "reduce_scatter", "pgather", "axis_index",
)) - {"axis_index"}

# Primitives with their own sub-jaxprs the analyzer recurses into as plain
# straight-line code (divergence/taint map input-position → input-position).
_INLINE_CALL_PRIMS = (
    "pjit", "closed_call", "core_call", "custom_jvp_call",
    "custom_vjp_call", "custom_vjp_call_jaxpr", "remat", "checkpoint",
)


def _jaxpr_of(obj):
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    return obj if hasattr(obj, "eqns") else None


def _axis_names(params: dict) -> tuple:
    """Axis names of a collective eqn: `axes` (psum/pmax/pmin) or
    `axis_name` (ppermute/all_gather/all_to_all); positional int axes are
    array axes, not mesh axes, and are skipped."""
    raw = params.get("axes", params.get("axis_name", ()))
    if not isinstance(raw, (tuple, list)):
        raw = (raw,)
    return tuple(a for a in raw if isinstance(a, str))


def _is_var(v) -> bool:
    return hasattr(v, "aval") and not hasattr(v, "val")


@dataclasses.dataclass
class ShardMapReport:
    """One shard_map equation's extracted collective structure."""

    target: str
    mesh_axes: dict          # axis name -> size
    collectives: list        # primitive names, program order

    def to_dict(self) -> dict:
        return {
            "target": self.target,
            "mesh_axes": dict(self.mesh_axes),
            "collectives": list(self.collectives),
        }


class _BodyChecker:
    """Divergence + head-taint walk over one shard_map body jaxpr."""

    def __init__(self, target: str, mesh_axes: dict, allowed_axes, findings,
                 collectives):
        self.target = target
        self.mesh_axes = mesh_axes
        self.allowed_axes = frozenset(allowed_axes) if allowed_axes else None
        self.findings = findings
        self.collectives = collectives
        self._seen_rules: set[tuple[str, str]] = set()

    # ------------------------------------------------------------- plumbing
    def _emit(self, rule_id: str, message: str, dedup: str = "") -> None:
        key = (rule_id, dedup or message)
        if key in self._seen_rules:
            return
        self._seen_rules.add(key)
        self.findings.append(Finding(rule_id, self.target, 0, message))

    @staticmethod
    def _in_set(vals, marked: set) -> bool:
        return any(_is_var(v) and v in marked for v in vals)

    # ------------------------------------------------------- per-collective
    def _check_collective(self, eqn, divergent: set, tainted: set,
                          under_divergent_ctl: bool) -> None:
        prim = eqn.primitive.name
        self.collectives.append(prim)
        if under_divergent_ctl:
            self._emit(
                "coll-divergent-control",
                f"`{prim}` executes under shard-divergent control flow — "
                "shards disagreeing on the branch/trip count deadlock the "
                "collective",
                dedup=prim,
            )
        names = _axis_names(eqn.params)
        for name in names:
            if name not in self.mesh_axes:
                self._emit(
                    "coll-axis-name",
                    f"`{prim}` over axis {name!r} which is not an axis of "
                    f"the enclosing shard_map mesh {sorted(self.mesh_axes)}",
                    dedup=f"{prim}:{name}:mesh",
                )
            elif self.allowed_axes is not None and name not in self.allowed_axes:
                self._emit(
                    "coll-axis-name",
                    f"`{prim}` over axis {name!r} outside the engine's "
                    f"declared axis set {sorted(self.allowed_axes)}",
                    dedup=f"{prim}:{name}:allowed",
                )
        if prim == "ppermute":
            self._check_ppermute(eqn, names)
        if prim in GATHER_COLLECTIVES and self._in_set(eqn.invars, tainted):
            self._emit(
                "coll-head-gather",
                f"head-STwig table reaches `{prim}` — Theorem 5 requires "
                "the head to stay shard-local (remote head rows break "
                "per-shard page disjointness)",
                dedup=prim,
            )

    def _check_ppermute(self, eqn, names) -> None:
        perm = tuple(eqn.params.get("perm", ()))
        sizes = [self.mesh_axes[n] for n in names if n in self.mesh_axes]
        if not sizes:
            return
        n = sizes[0]
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        ok = (
            len(perm) == n
            and sorted(srcs) == list(range(n))
            and sorted(dsts) == list(range(n))
        )
        if not ok:
            self._emit(
                "coll-ppermute-bijection",
                f"perm {perm!r} is not a bijection over the {n}-shard mesh "
                "axis — every shard must send exactly once and receive "
                "exactly once (missing pairs silently zero-fill the "
                "destination)",
                dedup=repr(perm),
            )

    # ------------------------------------------------------------ the walk
    def walk(self, jaxpr, divergent: set, tainted: set,
             under_divergent_ctl: bool = False) -> tuple[set, set]:
        """Walk one (sub-)jaxpr given the divergence/taint of its invars;
        returns (divergent outvars, tainted outvars) as var sets."""
        j = _jaxpr_of(jaxpr)
        if j is None:
            return set(), set()
        div = set(divergent)
        tnt = set(tainted)
        for eqn in j.eqns:
            prim = eqn.primitive.name
            in_div = self._in_set(eqn.invars, div)
            in_tnt = self._in_set(eqn.invars, tnt)

            if prim in COLLECTIVE_PRIMS:
                self._check_collective(eqn, div, tnt, under_divergent_ctl)
                # full-axis reductions/gathers produce replicated values;
                # grouped variants and ppermute stay per-shard
                grouped = eqn.params.get("axis_index_groups") is not None
                converges = (
                    prim in REDUCE_COLLECTIVES + ("all_gather",)
                    and not grouped
                )
                out_div = in_div and not converges
                out_tnt = in_tnt
            elif prim == "axis_index":
                out_div, out_tnt = True, False
            elif prim == "cond":
                out_div, out_tnt = self._walk_cond(
                    eqn, div, tnt, under_divergent_ctl
                )
            elif prim == "while":
                out_div, out_tnt = self._walk_while(
                    eqn, div, tnt, under_divergent_ctl
                )
            elif prim == "scan":
                # static trip count: every shard runs the same number of
                # iterations, so the loop itself cannot diverge
                out_div, out_tnt = self._walk_mapped_sub(
                    eqn, "jaxpr", div, tnt, under_divergent_ctl
                )
            elif prim in _INLINE_CALL_PRIMS or "jaxpr" in eqn.params:
                out_div, out_tnt = self._walk_mapped_sub(
                    eqn, "jaxpr", div, tnt, under_divergent_ctl
                )
            else:
                out_div, out_tnt = in_div, in_tnt

            for v in eqn.outvars:
                if not _is_var(v):
                    continue
                if out_div:
                    div.add(v)
                if out_tnt:
                    tnt.add(v)
        out_div_set = {v for v in j.outvars if _is_var(v) and v in div}
        out_tnt_set = {v for v in j.outvars if _is_var(v) and v in tnt}
        return out_div_set, out_tnt_set

    def _map_into(self, sub_jaxpr, eqn_invars, div, tnt):
        """Positional divergence/taint mapping from eqn invars to sub-jaxpr
        invars (trailing eqn invars map to trailing sub invars)."""
        j = _jaxpr_of(sub_jaxpr)
        sub_div, sub_tnt = set(), set()
        if j is None:
            return sub_div, sub_tnt
        n = min(len(j.invars), len(eqn_invars))
        outer = list(eqn_invars)[-n:]
        inner = list(j.invars)[-n:]
        for o, i in zip(outer, inner):
            if _is_var(o) and o in div:
                sub_div.add(i)
            if _is_var(o) and o in tnt:
                sub_tnt.add(i)
        return sub_div, sub_tnt

    def _walk_mapped_sub(self, eqn, param, div, tnt, under):
        subs = eqn.params.get(param)
        if subs is None:
            subs = [v for v in eqn.params.values() if _jaxpr_of(v) is not None]
        if not isinstance(subs, (tuple, list)):
            subs = [subs]
        any_div = any_tnt = False
        for sub in subs:
            sub_div, sub_tnt = self._map_into(sub, eqn.invars, div, tnt)
            o_div, o_tnt = self.walk(sub, sub_div, sub_tnt, under)
            any_div |= bool(o_div) or self._in_set(eqn.invars, div)
            any_tnt |= bool(o_tnt) or self._in_set(eqn.invars, tnt)
        return any_div, any_tnt

    def _walk_cond(self, eqn, div, tnt, under):
        pred = eqn.invars[0]
        pred_div = _is_var(pred) and pred in div
        branches = eqn.params.get("branches", ())
        any_div = self._in_set(eqn.invars, div)
        any_tnt = self._in_set(eqn.invars, tnt)
        for br in branches:
            sub_div, sub_tnt = self._map_into(br, eqn.invars[1:], div, tnt)
            o_div, o_tnt = self.walk(
                br, sub_div, sub_tnt, under or pred_div
            )
            any_div |= bool(o_div)
            any_tnt |= bool(o_tnt)
        return any_div or pred_div, any_tnt

    def _walk_while(self, eqn, div, tnt, under):
        cond_jaxpr = eqn.params["cond_jaxpr"]
        body_jaxpr = eqn.params["body_jaxpr"]
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        cond_consts = eqn.invars[:cn]
        body_consts = eqn.invars[cn:cn + bn]
        carry = eqn.invars[cn + bn:]
        # the predicate reads cond consts + the carry; divergence of either
        # makes the trip count shard-dependent
        pred_div = self._in_set(list(cond_consts) + list(carry), div)
        # check the cond jaxpr itself (a collective inside the predicate
        # body is legal only when convergent, same walk applies)
        c_div, _ = self._map_into(
            cond_jaxpr, list(cond_consts) + list(carry), div, tnt
        )
        self.walk(cond_jaxpr, c_div, set(), under or pred_div)
        b_div, b_tnt = self._map_into(
            body_jaxpr, list(body_consts) + list(carry), div, tnt
        )
        o_div, o_tnt = self.walk(
            body_jaxpr, b_div, b_tnt, under or pred_div
        )
        any_div = pred_div or bool(o_div) or self._in_set(eqn.invars, div)
        any_tnt = bool(o_tnt) or self._in_set(eqn.invars, tnt)
        return any_div, any_tnt


def _iter_shard_maps(jaxpr):
    """Yield every shard_map eqn in ``jaxpr`` (recursing through wrappers)."""
    j = _jaxpr_of(jaxpr)
    if j is None:
        return
    for eqn in j.eqns:
        if eqn.primitive.name == "shard_map":
            yield eqn
            continue
        for v in eqn.params.values():
            stack = [v]
            while stack:
                x = stack.pop()
                if isinstance(x, (tuple, list)):
                    stack.extend(x)
                    continue
                sub = _jaxpr_of(x)
                if sub is not None:
                    yield from _iter_shard_maps(sub)


def _mesh_axes(eqn) -> dict:
    mesh = eqn.params.get("mesh")
    shape = getattr(mesh, "shape", None)
    return dict(shape) if shape else {}


def check_collective_safety(
    jaxpr,
    target: str,
    *,
    allowed_axes: Iterable[str] | None = None,
    head_invars: Sequence[int] = (),
    reports: list | None = None,
) -> list[Finding]:
    """Walk one traced entry point. ``head_invars`` are the positions of the
    head-STwig table in each shard_map body's flattened invars (Theorem-5
    taint sources); ``allowed_axes`` is the engine's declared axis set."""
    findings: list[Finding] = []
    for eqn in _iter_shard_maps(jaxpr):
        body = _jaxpr_of(eqn.params.get("jaxpr"))
        if body is None:  # pragma: no cover - jax internals moved
            continue
        mesh_axes = _mesh_axes(eqn)
        in_names = eqn.params.get("in_names", ())
        divergent = set()
        for i, v in enumerate(body.invars):
            names = in_names[i] if i < len(in_names) else {"sharded": 1}
            if names:  # any named axis entry ⇒ per-shard data
                divergent.add(v)
        tainted = {
            body.invars[i] for i in head_invars if i < len(body.invars)
        }
        collectives: list[str] = []
        checker = _BodyChecker(
            target, mesh_axes, allowed_axes, findings, collectives
        )
        checker.walk(body, divergent, tainted)
        if reports is not None:
            reports.append(
                ShardMapReport(target, mesh_axes, collectives)
            )
    return findings


# ----------------------------------------------------- engine-key plumbing
def head_taints_for_key(key) -> tuple[int, ...]:
    """Positions of the head-STwig table in the shard_map body invars of a
    recorded sharded-engine executable, derived from its cache key.

    The sharded engine flattens its shard_map arguments in declaration
    order (`repro.core.dist`):

      * ``dist_join``        — body(tables, valids, load): head at
        ``head_pos`` (key[3]) and ``n + head_pos`` with n = len(schemas);
      * ``dist_gather``      — body(tables, valids, load): head at
        ``head_pos`` (key[2]) and ``n + head_pos`` with n = key[1];
      * ``dist_join_block``  — body(head_cols, head_valid, g_cols,
        g_valids, lo): head at 0 and 1;
      * everything else      — no head operand.
    """
    if not (isinstance(key, tuple) and key and isinstance(key[0], str)):
        return ()
    head = key[0]
    try:
        if head == "dist_join":
            n = len(key[1])
            pos = int(key[3])
            return (pos, n + pos)
        if head == "dist_gather":
            n = int(key[1])
            pos = int(key[2])
            return (pos, n + pos)
        if head == "dist_join_block":
            return (0, 1)
    except (IndexError, TypeError, ValueError):  # pragma: no cover
        return ()
    return ()


def check_traces(
    traces,
    *,
    allowed_axes: Iterable[str] | None = None,
    reports: list | None = None,
) -> list[Finding]:
    """Run the pass over engine-probe traces (`engines.EntryTrace`)."""
    if allowed_axes is None:
        from repro.core.dist import AXIS

        allowed_axes = (AXIS,)
    findings: list[Finding] = []
    for t in traces:
        findings.extend(check_collective_safety(
            t.jaxpr,
            t.target,
            allowed_axes=allowed_axes,
            head_invars=head_taints_for_key(t.key),
            reports=reports,
        ))
    return findings
