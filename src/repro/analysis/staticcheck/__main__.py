"""CLI: ``python -m repro.analysis.staticcheck [--json] [--no-engines]
[--no-collectives] [--no-costmodel] [--x64] [--root DIR]``. Exit status 1
when any finding survives, 0 on a clean tree — the CI gate
(`.github/workflows/ci.yml` staticcheck job). ``--json`` additionally
carries the collective-sequence and per-executable cost reports."""
from __future__ import annotations

import argparse
import pathlib
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.staticcheck",
        description="jaxpr contracts + retrace detector + architecture lint",
    )
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable report on stdout")
    ap.add_argument("--no-engines", action="store_true",
                    help="skip the live engine probe (pure static + abstract "
                         "tracing only; seconds instead of a minute) — also "
                         "skips the trace-driven collective-safety and "
                         "cost-model passes")
    ap.add_argument("--no-collectives", action="store_true",
                    help="skip the collective-safety pass over the sharded "
                         "engine traces")
    ap.add_argument("--no-costmodel", action="store_true",
                    help="skip the static cost model (budgets.json "
                         "enforcement + linear-memory scaling probe)")
    ap.add_argument("--x64", action="store_true",
                    help="trace kernel contracts and engine probes with jax "
                         "x64 enabled to surface weak-type promotions; "
                         "restricts to the `jnp` backend (pallas "
                         "interpret-mode emulation runs its grid loop in "
                         "int64 by itself)")
    ap.add_argument("--root", type=pathlib.Path, default=None,
                    help="repo root (default: inferred from this file)")
    args = ap.parse_args(argv)

    if args.x64:
        import jax

        jax.config.update("jax_enable_x64", True)

    from repro.analysis.staticcheck import report_json, run_all

    reports: dict = {}
    findings = run_all(
        args.root,
        engines=not args.no_engines,
        kernel_backends=("jnp",) if args.x64 else None,
        collectives=not args.no_collectives,
        costs=not args.no_costmodel,
        reports=reports,
    )
    if args.json:
        print(report_json(findings, extras=reports))
    else:
        for f in findings:
            print(f)
        print(f"staticcheck: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
