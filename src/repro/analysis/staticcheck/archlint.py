"""Architecture lint (staticcheck pass c): the repo's layering rules as
named, suppressible AST rules.

Each rule codifies an invariant that used to be folklore in CHANGES.md:

  * ``bitset-twiddling``        — packed-word bit arithmetic lives ONLY in
                                  ``kernels/bitset/`` (DESIGN.md §2);
  * ``module-jit-state``        — no module-level ``lru_cache``/``jit``
                                  executable state (sessions own caches);
  * ``direct-engine-construction`` — engines are built by
                                  ``api/session.py`` only;
  * ``stream-host-sync``        — no host syncs inside a loop consuming
                                  ``stream()``/``stream_blocks()`` pages;
  * ``missing-slow-marker``     — subprocess/e2e test modules carry the
                                  ``slow`` pytest marker;
  * ``orphan-module``           — every ``src`` module is reachable from a
                                  test/benchmark/example/script or a declared
                                  CLI entry point (``extras/`` is the
                                  quarantine boundary and is exempt);
  * ``unused-import``           — no dead imports in ``src``.

Suppress a specific line with ``# staticcheck: ignore[rule-id]``.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterator

from repro.analysis.staticcheck.findings import (
    Finding,
    is_suppressed,
    rule,
    suppressed_lines,
)

rule("bitset-twiddling", "kernels/bitset",
     "packed-bitset word arithmetic (>>5, &31, %32, //32, popcount masks) "
     "outside kernels/bitset/")
rule("module-jit-state", "api/session",
     "module-level lru_cache or import-time jax.jit executable state")
rule("direct-engine-construction", "api/session",
     "SubgraphMatcher/DistributedMatcher constructed outside api/session.py")
rule("stream-host-sync", "core/stream",
     "jax.device_get/.block_until_ready() inside a stream-consuming loop")
rule("missing-slow-marker", "ci",
     "subprocess/e2e test module without the `slow` pytest marker")
rule("orphan-module", "repo layout",
     "src module unreachable from tests/benchmarks/examples/scripts or a "
     "declared entry point (quarantine dead scaffolding under repro/extras/)")
rule("unused-import", "hygiene", "import never referenced in the module")

# Paths (relative, substring match) where each rule does not apply.
BITSET_ALLOWED = ("kernels/bitset/",)
ENGINE_CTOR_ALLOWED = ("api/session.py",)
# CLI entry points reached via `python -m`, not imports. repro/extras/ is the
# one sanctioned home for not-yet-wired scaffolding and is exempt wholesale.
ENTRY_POINT_MODULES = {
    "repro.launch.serve",
    "repro.analysis.staticcheck.__main__",  # the staticcheck CLI itself
}
ORPHAN_EXEMPT_DIRS = ("repro/extras/",)

_POPCOUNT_MASKS = {0x55555555, 0x33333333, 0x0F0F0F0F, 0x01010101}
_WORD_NAMES = {"WORD_BITS"}
_IDENT_RE = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")


def _py_files(root: str) -> Iterator[str]:
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for f in sorted(filenames):
            if f.endswith(".py"):
                yield os.path.join(dirpath, f)


def _const_of(node: ast.AST):
    """Unwrap `31`, `np.uint32(31)`, `jnp.uint32(31)` → 31."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if (
        isinstance(node, ast.Call)
        and len(node.args) == 1
        and not node.keywords
        and isinstance(node.args[0], ast.Constant)
        and isinstance(node.args[0].value, int)
    ):
        return node.args[0].value
    return None


def _is_word_name(node: ast.AST) -> bool:
    return (isinstance(node, ast.Name) and node.id in _WORD_NAMES) or (
        isinstance(node, ast.Attribute) and node.attr in _WORD_NAMES
    )


def _rel(path: str, repo_root: str) -> str:
    return os.path.relpath(path, repo_root)


# ------------------------------------------------------------- per-file rules
def _check_bitset_twiddling(tree, relpath, sup):
    if any(a in relpath for a in BITSET_ALLOWED):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.BinOp):
            continue
        c = _const_of(node.right)
        word_name = _is_word_name(node.right)
        bad = None
        if isinstance(node.op, (ast.RShift, ast.LShift)) and c == 5:
            bad = "word-index shift by 5"
        elif isinstance(node.op, ast.BitAnd) and (
            c == 31 or c in _POPCOUNT_MASKS
        ):
            bad = f"bit-extract mask {c if c == 31 else hex(c)}"
        elif isinstance(node.op, (ast.Mod, ast.FloorDiv)) and (
            c == 32 or word_name
        ):
            bad = "word-size divide/modulo"
        if bad and not is_suppressed(sup, node.lineno, "bitset-twiddling"):
            yield Finding(
                "bitset-twiddling", relpath, node.lineno,
                f"{bad}: packed-bitset arithmetic belongs in "
                "kernels/bitset/ (DESIGN.md §2)",
            )


def _check_module_jit_state(tree, relpath, sup):
    def deco_is_cache(d: ast.AST) -> bool:
        target = d.func if isinstance(d, ast.Call) else d
        name = target.attr if isinstance(target, ast.Attribute) else (
            target.id if isinstance(target, ast.Name) else ""
        )
        return name in ("lru_cache", "cache")

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for d in node.decorator_list:
                if deco_is_cache(d) and not is_suppressed(
                    sup, node.lineno, "module-jit-state"
                ):
                    yield Finding(
                        "module-jit-state", relpath, node.lineno,
                        f"`{node.name}` holds process-global lru_cache state "
                        "— key executables in a session-owned "
                        "ExecutableCache instead",
                    )
    for node in tree.body:  # import-time jit: module scope only
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            value = node.value
            if (
                isinstance(value, ast.Call)
                and isinstance(value.func, (ast.Name, ast.Attribute))
                and (
                    value.func.attr
                    if isinstance(value.func, ast.Attribute)
                    else value.func.id
                )
                == "jit"
                and not is_suppressed(sup, node.lineno, "module-jit-state")
            ):
                yield Finding(
                    "module-jit-state", relpath, node.lineno,
                    "module-level jax.jit executable built at import time",
                )


def _check_engine_construction(tree, relpath, sup):
    if any(a in relpath for a in ENGINE_CTOR_ALLOWED):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        name = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else ""
        )
        if name in ("SubgraphMatcher", "DistributedMatcher") and not (
            is_suppressed(sup, node.lineno, "direct-engine-construction")
        ):
            yield Finding(
                "direct-engine-construction", relpath, node.lineno,
                f"direct {name} construction — open a GraphSession instead "
                "(engines are deprecated construction targets)",
            )


def _iter_stream_loops(tree) -> Iterator[ast.For]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.For):
            continue
        for sub in ast.walk(node.iter):
            if isinstance(sub, ast.Call):
                f = sub.func
                name = f.attr if isinstance(f, ast.Attribute) else (
                    f.id if isinstance(f, ast.Name) else ""
                )
                if name in ("stream", "match_stream", "stream_blocks"):
                    yield node
                    break
        else:
            continue


def _check_stream_host_sync(tree, relpath, sup):
    for loop in _iter_stream_loops(tree):
        for node in ast.walk(loop):
            if node is loop.iter or not isinstance(node, ast.Call):
                continue
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else ""
            )
            if name in ("device_get", "block_until_ready") and not (
                is_suppressed(sup, node.lineno, "stream-host-sync")
            ):
                yield Finding(
                    "stream-host-sync", relpath, node.lineno,
                    f"{name}() inside a stream-consuming loop defeats "
                    "pipelined first-K delivery (pages are already host "
                    "numpy; sync before or after the loop)",
                )


def _check_slow_marker(tree, relpath, sup, source):
    if "/tests/" not in "/" + relpath and not relpath.startswith("tests/"):
        return
    uses = [
        n for n in ast.walk(tree)
        if isinstance(n, (ast.Import, ast.ImportFrom))
        and any(
            (a.name if isinstance(n, ast.Import) else (n.module or ""))
            .split(".")[0] == "subprocess"
            for a in n.names
        )
    ]
    if not uses:
        return
    if re.search(r"^pytestmark\s*=.*\bslow\b", source, re.M):
        return
    # per-function markers: every function whose body reaches subprocess
    # must be marked slow
    for fn in [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)]:
        touches = any(
            isinstance(n, (ast.Import, ast.ImportFrom, ast.Name, ast.Attribute))
            and "subprocess" in ast.dump(n)
            for n in ast.walk(fn)
        )
        if not touches:
            continue
        marked = any("slow" in ast.dump(d) for d in fn.decorator_list)
        if not marked and not is_suppressed(sup, fn.lineno, "missing-slow-marker"):
            yield Finding(
                "missing-slow-marker", relpath, fn.lineno,
                f"`{fn.name}` spawns subprocesses without a `slow` marker — "
                "mark it (or the module) so the fast CI job skips it",
            )


def _check_unused_imports(tree, relpath, sup, source):
    if not relpath.startswith("src/") or relpath.endswith("__init__.py"):
        return
    # names used anywhere: identifiers + identifiers inside string constants
    # (string annotations under `from __future__ import annotations`)
    used: set[str] = set()
    tc_linenos: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            used.update(_IDENT_RE.findall(node.value))
        elif isinstance(node, ast.If):
            t = node.test
            is_tc = (isinstance(t, ast.Name) and t.id == "TYPE_CHECKING") or (
                isinstance(t, ast.Attribute) and t.attr == "TYPE_CHECKING"
            )
            if is_tc:
                for sub in ast.walk(node):
                    tc_linenos.add(getattr(sub, "lineno", 0))
    lines = source.splitlines()
    for node in ast.walk(tree):
        if not isinstance(node, (ast.Import, ast.ImportFrom)):
            continue
        if isinstance(node, ast.ImportFrom) and node.module == "__future__":
            continue
        if node.lineno in tc_linenos:
            continue
        for a in node.names:
            if a.name == "*":
                continue
            bound = (a.asname or a.name).split(".")[0]
            if bound in used:
                continue
            text = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
            if "noqa" in text:
                continue
            if is_suppressed(sup, node.lineno, "unused-import"):
                continue
            yield Finding(
                "unused-import", relpath, node.lineno,
                f"`{bound}` is imported but never used",
            )


# -------------------------------------------------------------- orphan pass
def _module_name(relpath: str) -> str | None:
    if not relpath.startswith("src/"):
        return None
    mod = relpath[len("src/"):-len(".py")].replace("/", ".")
    if mod.endswith(".__init__"):
        mod = mod[: -len(".__init__")]
    return mod


def _imports_of(tree) -> set[str]:
    out: set[str] = set()
    for n in ast.walk(tree):
        if isinstance(n, ast.Import):
            out.update(a.name for a in n.names)
        elif isinstance(n, ast.ImportFrom) and n.module:
            out.add(n.module)
            out.update(f"{n.module}.{a.name}" for a in n.names)
    return out


def _check_orphans(parsed: dict[str, ast.Module]):
    """Reachability over the import graph: roots are every non-src file plus
    the declared CLI entry points; anything in src not reached is dead
    scaffolding (exempt: repro/extras/, the explicit quarantine)."""
    mods = {}
    for relpath in parsed:
        m = _module_name(relpath)
        if m is not None:
            mods[m] = relpath
    reached: set[str] = set()
    frontier: list[str] = list(ENTRY_POINT_MODULES)
    for relpath, tree in parsed.items():
        if not relpath.startswith("src/"):
            frontier.extend(m for m in _imports_of(tree) if m in mods)
    while frontier:
        m = frontier.pop()
        if m in reached or m not in mods:
            continue
        reached.add(m)
        parts = m.split(".")
        frontier.extend(
            ".".join(parts[:i]) for i in range(1, len(parts))
        )  # parent packages (their __init__ runs on import)
        frontier.extend(
            im for im in _imports_of(parsed[mods[m]]) if im in mods
        )
    for m, relpath in sorted(mods.items()):
        if m in reached or any(d in relpath for d in ORPHAN_EXEMPT_DIRS):
            continue
        yield Finding(
            "orphan-module", relpath, 1,
            f"`{m}` is unreachable from every test/benchmark/example/script "
            "and is not a declared entry point — delete it or quarantine it "
            "under src/repro/extras/",
        )


# ----------------------------------------------------------------- entry
def run(repo_root: str) -> list[Finding]:
    roots = ["src", "tests", "benchmarks", "examples", "scripts"]
    parsed: dict[str, ast.Module] = {}
    sources: dict[str, str] = {}
    findings: list[Finding] = []
    for r in roots:
        absroot = os.path.join(repo_root, r)
        if not os.path.isdir(absroot):
            continue
        for path in _py_files(absroot):
            relpath = _rel(path, repo_root)
            try:
                with open(path, encoding="utf-8") as fh:
                    src = fh.read()
                parsed[relpath] = ast.parse(src)
                sources[relpath] = src
            except SyntaxError as e:
                findings.append(
                    Finding("orphan-module", relpath, e.lineno or 1,
                            f"unparseable: {e.msg}")
                )
    for relpath, tree in parsed.items():
        sup = suppressed_lines(sources[relpath])
        src = sources[relpath]
        in_src = relpath.startswith("src/")
        if in_src:
            findings.extend(_check_bitset_twiddling(tree, relpath, sup))
            findings.extend(_check_module_jit_state(tree, relpath, sup))
            findings.extend(_check_unused_imports(tree, relpath, sup, src))
        if in_src or relpath.split("/")[0] in ("benchmarks", "examples", "scripts"):
            findings.extend(_check_engine_construction(tree, relpath, sup))
            findings.extend(_check_stream_host_sync(tree, relpath, sup))
        if relpath.startswith("tests/"):
            findings.extend(_check_slow_marker(tree, relpath, sup, src))
    findings.extend(_check_orphans(parsed))
    return findings
