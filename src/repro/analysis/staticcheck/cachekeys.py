"""Cache-key coverage (staticcheck static companion to the retrace rule).

For every ``<...>.cache.get(key, builder)`` call site in ``src/``, the
builder closure's free variables that are locals of the enclosing function
(parameters, assignments — anything that can vary between calls) must each
appear in the key expression. A closed-over local missing from the key is
exactly how silent retraces happen: two calls with different static state
hash to the same logical key and the jitted executable re-traces under it
(`ExecutableCache.retraced_executables` catches the runtime symptom; this
pass catches it before it runs).

Names that are not enclosing-function locals — module globals, ``self`` —
are exempt: they do not vary call-to-call at one site.
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.staticcheck.findings import (
    Finding,
    is_suppressed,
    rule,
    suppressed_lines,
)

rule("cache-key-coverage", "engine",
     "an executable-cache builder closes over a local that is missing from "
     "its cache key")

RULE = "cache-key-coverage"


def _is_cache_get(call: ast.Call) -> bool:
    f = call.func
    if not (isinstance(f, ast.Attribute) and f.attr == "get"):
        return False
    v = f.value
    return (isinstance(v, ast.Attribute) and v.attr == "cache") or (
        isinstance(v, ast.Name) and v.id == "cache"
    )


def _names_loaded(node: ast.AST) -> set[str]:
    return {
        n.id for n in ast.walk(node)
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
    }


def _names_stored(node: ast.AST) -> set[str]:
    return {
        n.id for n in ast.walk(node)
        if isinstance(n, ast.Name) and isinstance(n.ctx, (ast.Store, ast.Del))
    }


def _arg_names(fn: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda) -> set[str]:
    a = fn.args
    names = {x.arg for x in a.posonlyargs + a.args + a.kwonlyargs}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    return names


def _free_names(builder: ast.AST, enclosing) -> set[str]:
    """Free variables of the builder: loads minus its own params/locals.
    For a ``Name`` builder, resolve the local ``def`` of that name inside
    the enclosing function."""
    if isinstance(builder, ast.Lambda):
        return _names_loaded(builder.body) - _arg_names(builder)
    if isinstance(builder, ast.Name) and enclosing is not None:
        for n in ast.walk(enclosing):
            if (
                isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                and n.name == builder.id
            ):
                bound = _arg_names(n) | _names_stored(n)
                return _names_loaded(n) - bound - {builder.id}
    # builder shapes we cannot resolve statically (an attribute, a call
    # result): nothing to check — the runtime retrace rule still covers them
    return set()


def check_file(path: pathlib.Path, rel: str) -> list[Finding]:
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as e:
        return [Finding(RULE, rel, e.lineno or 0, f"unparseable: {e.msg}")]
    sup = suppressed_lines(source)

    # parent links so each call site can find its enclosing function
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node

    findings: list[Finding] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _is_cache_get(node)):
            continue
        if len(node.args) < 2:
            continue
        key_expr, builder = node.args[0], node.args[1]
        enclosing = node
        while enclosing is not None and not isinstance(
            enclosing, (ast.FunctionDef, ast.AsyncFunctionDef)
        ):
            enclosing = parents.get(enclosing)
        if enclosing is None:
            continue
        local_names = (
            _arg_names(enclosing) | _names_stored(enclosing)
        ) - {"self", "cls"}
        key_names = _names_loaded(key_expr)
        if isinstance(key_expr, ast.Name):
            # `key = (...)` assigned above the call: the assignment's value
            # is the key expression
            for n in ast.walk(enclosing):
                if (
                    isinstance(n, ast.Assign)
                    and len(n.targets) == 1
                    and isinstance(n.targets[0], ast.Name)
                    and n.targets[0].id == key_expr.id
                ):
                    key_names |= _names_loaded(n.value)
        missing = sorted(
            (_free_names(builder, enclosing) & local_names) - key_names
        )
        if missing and not is_suppressed(sup, node.lineno, RULE):
            findings.append(Finding(
                RULE, rel, node.lineno,
                f"builder closes over local(s) {missing} not present in the "
                "cache key — vary them and the executable silently "
                "re-traces under one key",
            ))
    return findings


def check_cache_keys(repo_root: pathlib.Path) -> list[Finding]:
    findings: list[Finding] = []
    src = repo_root / "src"
    for path in sorted(src.rglob("*.py")):
        findings.extend(check_file(path, str(path.relative_to(repo_root))))
    return findings
