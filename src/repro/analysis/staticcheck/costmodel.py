"""Static cost model (staticcheck pass e).

For every executable the engine probe recorded, estimate — from the jaxpr
alone, nothing executes —

  * ``peak_bytes``        — peak resident buffer bytes via a liveness scan
    (a var is live from its defining equation to its last use; sub-jaxprs
    contribute their own internal peak at their call site);
  * ``flops``             — total floating/integer op count from a
    per-primitive table (dot_general counted exactly, elementwise ops at
    one per output element, sorts at n·log2 n);
  * ``collective_bytes``  — bytes moved by cross-shard collectives, using
    the SAME conventions as `repro.analysis.roofline.parse_collectives`
    (result-shape bytes × ring multiplier: all-reduce 2(n−1)/n,
    all-gather/reduce-scatter (n−1)/n, permute 1×), so the two estimates
    cross-check against each other within tolerance on real kernels.

The per-entry-point report is emitted under ``cost_report`` in the CLI's
``--json`` output and enforced against `src/repro/analysis/budgets.json`:

  * ``cost-budget-exceeded``     — an entry point's estimate exceeds its
    checked-in ceiling for the fixed probe workload (a perf/memory
    regression CI refuses);
  * ``cost-budget-missing``      — an entry point with no budget row fails
    CLOSED: new collectives/entry points must declare their budget;
  * ``cost-superlinear-memory``  — the paper's core constraint: peak
    resident bytes must stay linear in graph size. The probe runs two
    generator sizes and the per-entry-point growth ratio must stay under
    ``linear_slack × size_ratio`` (slack absorbs power-of-two capacity
    rounding, which alone can double a linear quantity).
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib

from repro.analysis.staticcheck.findings import Finding, rule

rule("cost-budget-exceeded", "costmodel",
     "entry-point cost estimate exceeds its budgets.json ceiling for the "
     "probe workload")
rule("cost-budget-missing", "costmodel",
     "entry point has no budgets.json row (the cost pass fails closed: "
     "new entry points must declare budgets)")
rule("cost-superlinear-memory", "costmodel",
     "peak resident bytes grow superlinearly in graph size across the two "
     "probe generator sizes (the paper's linear-space constraint)")

BUDGETS_PATH = pathlib.Path(__file__).resolve().parents[1] / "budgets.json"

_DTYPE_BYTES = {
    "bool": 1, "int8": 1, "uint8": 1, "int16": 2, "uint16": 2,
    "bfloat16": 2, "float16": 2, "int32": 4, "uint32": 4, "float32": 4,
    "int64": 8, "uint64": 8, "float64": 8, "complex64": 8, "complex128": 16,
}

# one-flop-per-output-element primitives (elementwise arithmetic & compares)
_ELEMENTWISE = frozenset({
    "add", "sub", "mul", "div", "rem", "max", "min", "pow", "integer_pow",
    "exp", "log", "tanh", "logistic", "sqrt", "rsqrt", "abs", "neg", "sign",
    "floor", "ceil", "round", "erf", "and", "or", "xor", "not",
    "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "eq", "ne", "lt", "le", "gt", "ge", "select_n", "clamp", "nextafter",
    "convert_element_type", "cumsum", "cummax", "cummin", "cumprod",
    "population_count", "clz", "add_any",
})

# one-flop-per-INPUT-element reductions
_REDUCTIONS = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "reduce_xor", "argmax", "argmin", "reduce_precision",
})

# jaxpr collective primitive -> HLO kind used by roofline.parse_collectives
_COLL_KIND = {
    "psum": "all-reduce",
    "pmax": "all-reduce",
    "pmin": "all-reduce",
    "all_gather": "all-gather",
    "reduce_scatter": "reduce-scatter",
    "all_to_all": "all-to-all",
    "ppermute": "collective-permute",
}


def _jaxpr_of(obj):
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    return obj if hasattr(obj, "eqns") else None


def _is_var(v) -> bool:
    return hasattr(v, "aval") and not hasattr(v, "val")


def _aval_bytes(v) -> int:
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    n = 1
    for d in shape:
        try:
            n *= int(d)
        except TypeError:  # symbolic dim
            return 0
    return n * _DTYPE_BYTES.get(str(dtype), 4)


def _nelems(v) -> int:
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", ())
    n = 1
    for d in shape:
        try:
            n *= int(d)
        except TypeError:
            return 0
    return n


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        stack = [v]
        while stack:
            x = stack.pop()
            if isinstance(x, (tuple, list)):
                stack.extend(x)
                continue
            j = _jaxpr_of(x)
            if j is not None:
                yield j


def _scan_length(eqn) -> int:
    return max(int(eqn.params.get("length", 1)), 1)


# ------------------------------------------------------------------- flops
def _dot_general_flops(eqn) -> float:
    (lhs_c, _), _ = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0]
    contract = 1
    shape = getattr(lhs.aval, "shape", ())
    for d in lhs_c:
        contract *= int(shape[d])
    out = _nelems(eqn.outvars[0])
    return 2.0 * out * contract


def eqn_flops(eqn) -> float:
    """FLOPs of one equation, excluding sub-jaxpr bodies (those are walked
    separately so loop trip counts can scale them)."""
    prim = eqn.primitive.name
    if prim == "dot_general":
        return _dot_general_flops(eqn)
    if prim in _ELEMENTWISE:
        return float(_nelems(eqn.outvars[0]))
    if prim in _REDUCTIONS:
        return float(_nelems(eqn.invars[0]))
    if prim == "sort":
        n = _nelems(eqn.invars[0])
        return float(n) * max(math.log2(max(n, 2)), 1.0)
    return 0.0


def jaxpr_flops(jaxpr) -> float:
    """Total FLOPs: own equations + sub-jaxprs (scan bodies × trip count;
    cond branches at the max — one branch executes, bound by the worst)."""
    j = _jaxpr_of(jaxpr)
    if j is None:
        return 0.0
    total = 0.0
    for eqn in j.eqns:
        total += eqn_flops(eqn)
        prim = eqn.primitive.name
        if prim == "cond":
            branches = eqn.params.get("branches", ())
            total += max((jaxpr_flops(b) for b in branches), default=0.0)
        elif prim == "scan":
            total += _scan_length(eqn) * jaxpr_flops(eqn.params["jaxpr"])
        elif prim == "while":
            # trip count is data-dependent; count one iteration (a floor —
            # budgets bound the static program, not the dynamic schedule)
            total += jaxpr_flops(eqn.params["cond_jaxpr"])
            total += jaxpr_flops(eqn.params["body_jaxpr"])
        else:
            for sub in _sub_jaxprs(eqn):
                total += jaxpr_flops(sub)
    return total


# --------------------------------------------------------------- liveness
def peak_bytes(jaxpr) -> float:
    """Peak resident buffer bytes by forward liveness scan.

    A var is resident from the equation that defines it (jaxpr inputs and
    constants from the start) until its last use; at each equation the
    resident set plus the equation's outputs plus the larger of its
    sub-jaxprs' internal peaks bounds the high-water mark. An estimate —
    XLA fuses and rematerializes — but a stable, order-preserving one: a
    program that materializes an O(n²) intermediate shows an O(n²) peak.
    """
    j = _jaxpr_of(jaxpr)
    if j is None:
        return 0.0
    last_use: dict = {}
    for i, eqn in enumerate(j.eqns):
        for v in eqn.invars:
            if _is_var(v):
                last_use[v] = i
    n_eqns = len(j.eqns)
    for v in j.outvars:
        if _is_var(v):
            last_use[v] = n_eqns
    live: dict = {}
    for v in tuple(j.invars) + tuple(getattr(j, "constvars", ())):
        if _is_var(v) and v in last_use:
            live[v] = _aval_bytes(v)
    resident = float(sum(live.values()))
    peak = resident
    for i, eqn in enumerate(j.eqns):
        out_bytes = sum(
            _aval_bytes(v) for v in eqn.outvars if _is_var(v)
        )
        inner = 0.0
        for sub in _sub_jaxprs(eqn):
            sub_peak = peak_bytes(sub)
            sub_io = sum(
                _aval_bytes(v)
                for v in tuple(_jaxpr_of(sub).invars)
                + tuple(_jaxpr_of(sub).outvars)
            )
            inner = max(inner, sub_peak - sub_io)
        peak = max(peak, resident + out_bytes + max(inner, 0.0))
        for v in eqn.outvars:
            if _is_var(v) and v in last_use and last_use[v] > i:
                live[v] = _aval_bytes(v)
                resident += live[v]
        for v in tuple(eqn.invars) + tuple(eqn.outvars):
            if _is_var(v) and last_use.get(v) == i and v in live:
                resident -= live.pop(v)
    return peak


# ------------------------------------------------------------- collectives
def collective_bytes(jaxpr, axis_sizes: dict | None = None) -> dict:
    """Bytes moved per HLO collective kind, roofline conventions (result
    bytes × ring multiplier). ``axis_sizes`` maps mesh axis name → size for
    collectives whose eqn carries no explicit size; shard_map meshes found
    during the walk override it."""
    out: dict[str, float] = {}

    def walk(jx, sizes):
        j = _jaxpr_of(jx)
        if j is None:
            return
        for eqn in j.eqns:
            prim = eqn.primitive.name
            if prim == "shard_map":
                mesh = eqn.params.get("mesh")
                sub_sizes = dict(getattr(mesh, "shape", {}) or sizes)
                walk(eqn.params.get("jaxpr"), sub_sizes)
                continue
            if prim in _COLL_KIND:
                kind = _COLL_KIND[prim]
                raw = eqn.params.get("axes", eqn.params.get("axis_name", ()))
                if not isinstance(raw, (tuple, list)):
                    raw = (raw,)
                names = [a for a in raw if isinstance(a, str)]
                n = 1
                for a in names:
                    n *= int(sizes.get(a, 1))
                if prim == "all_gather":
                    n = int(eqn.params.get("axis_size", n))
                ring = (n - 1) / max(n, 1)
                mult = {
                    "all-reduce": 2.0 * ring,
                    "all-gather": ring,
                    "reduce-scatter": ring,
                    "all-to-all": ring,
                    "collective-permute": 1.0,
                }[kind]
                b = sum(_aval_bytes(v) for v in eqn.outvars) * mult
                out[kind] = out.get(kind, 0.0) + b
            for sub in _sub_jaxprs(eqn):
                walk(sub, sizes)

    walk(jaxpr, dict(axis_sizes or {}))
    return out


# ---------------------------------------------------------------- estimate
@dataclasses.dataclass
class CostEstimate:
    target: str          # engine:<backend>:<kernels>:<key head>
    peak_bytes: float
    flops: float
    collective_bytes: float
    collective_by_kind: dict

    def to_dict(self) -> dict:
        return {
            "target": self.target,
            "peak_bytes": self.peak_bytes,
            "flops": self.flops,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": dict(self.collective_by_kind),
        }


def estimate(jaxpr, target: str = "") -> CostEstimate:
    by_kind = collective_bytes(jaxpr)
    return CostEstimate(
        target=target,
        peak_bytes=peak_bytes(jaxpr),
        flops=jaxpr_flops(jaxpr),
        collective_bytes=float(sum(by_kind.values())),
        collective_by_kind=by_kind,
    )


# ----------------------------------------------------------------- budgets
def load_budgets(path: "pathlib.Path | str | None" = None) -> dict:
    p = pathlib.Path(path) if path is not None else BUDGETS_PATH
    return json.loads(p.read_text())


def _budget_rel(path: "pathlib.Path | str | None") -> str:
    p = pathlib.Path(path) if path is not None else BUDGETS_PATH
    return f"src/repro/analysis/{p.name}"


def aggregate(estimates: "list[CostEstimate]") -> dict:
    """target → per-metric max across that target's executables."""
    worst: dict[str, dict] = {}
    for e in estimates:
        m = worst.setdefault(e.target, {
            "peak_bytes": 0.0, "flops": 0.0, "collective_bytes": 0.0,
        })
        m["peak_bytes"] = max(m["peak_bytes"], e.peak_bytes)
        m["flops"] = max(m["flops"], e.flops)
        m["collective_bytes"] = max(m["collective_bytes"], e.collective_bytes)
    return worst


def check_budgets(
    estimates: "list[CostEstimate]",
    budgets: dict | None = None,
    *,
    budgets_path: "pathlib.Path | str | None" = None,
) -> list[Finding]:
    """Enforce per-entry-point ceilings. Aggregation is a per-metric max
    over executables sharing one target (retries and block variants re-key
    the same entry point)."""
    if budgets is None:
        budgets = load_budgets(budgets_path)
    rel = _budget_rel(budgets_path)
    entries = budgets.get("entries", {})
    findings: list[Finding] = []
    worst = aggregate(estimates)
    for target, metrics in sorted(worst.items()):
        row = entries.get(target)
        if row is None:
            findings.append(Finding(
                "cost-budget-missing", target, 0,
                f"no budget row for this entry point in {rel} — the cost "
                "pass fails closed; add a ceiling for the probe workload",
            ))
            continue
        for metric, value in sorted(metrics.items()):
            ceiling = row.get(metric)
            if ceiling is not None and value > ceiling:
                findings.append(Finding(
                    "cost-budget-exceeded", target, 0,
                    f"{metric} {value:.3g} exceeds the checked-in ceiling "
                    f"{ceiling:.3g} ({rel}) — a cost regression on the "
                    "probe workload",
                ))
    return findings


def check_linear_memory(
    small: "list[CostEstimate]",
    big: "list[CostEstimate]",
    *,
    size_ratio: float,
    slack: float = 2.0,
) -> list[Finding]:
    """The paper's linear-space constraint, asserted across two generator
    sizes: per entry point, peak bytes at the bigger graph must stay within
    ``slack × size_ratio ×`` the smaller graph's peak. ``slack`` absorbs
    power-of-two capacity rounding (each rounded capacity can at most
    double a linear term); a quadratic structure shows ratio ≈ size_ratio²
    and fails for any size_ratio > slack."""
    findings: list[Finding] = []
    small_by = aggregate(small)
    big_by = aggregate(big)
    bound = slack * size_ratio
    for target, metrics in sorted(big_by.items()):
        base = small_by.get(target, {}).get("peak_bytes", 0.0)
        if base <= 0:
            continue
        ratio = metrics["peak_bytes"] / base
        if ratio > bound:
            findings.append(Finding(
                "cost-superlinear-memory", target, 0,
                f"peak bytes grew {ratio:.2f}x for a {size_ratio:.0f}x "
                f"graph (bound {bound:.1f}x) — resident memory must stay "
                "linear in graph size (PAPER.md core constraint)",
            ))
    return findings


# -------------------------------------------------------------- cross-check
def hlo_cross_check(fn, *args, n_devices: int | None = None) -> dict:
    """Compare this module's jaxpr estimates against the HLO-derived numbers
    `repro.analysis.roofline` uses: XLA's ``cost_analysis()`` FLOPs and
    `parse_collectives` over the optimized HLO text. Returns both sides;
    the test suite asserts agreement within 10% on the benchmarked kernels.
    """
    import jax

    from repro.analysis import roofline

    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    est = estimate(jax.make_jaxpr(jitted)(*args))
    compiled = jitted.lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # pragma: no cover - older jax returns a list
        ca = ca[0]
    n = n_devices if n_devices is not None else jax.device_count()
    hlo_coll = roofline.parse_collectives(compiled.as_text(), n)
    return {
        "est_flops": est.flops,
        "hlo_flops": float(ca.get("flops", 0.0)),
        "est_collective_bytes": est.collective_bytes,
        "hlo_collective_bytes": hlo_coll.total_bytes,
        "est_by_kind": est.collective_by_kind,
        "hlo_by_kind": hlo_coll.bytes_by_kind,
    }
