"""Jaxpr contract checker (staticcheck pass a).

Abstractly traces every registered `Kernels` op on every registered backend
against the `OpContract` table declared next to the ops in
`repro.core.backend`, then walks the jaxprs (recursing into pjit /
shard_map / pallas_call / scan sub-jaxprs) to enforce:

  * ``jaxpr-out-dtype``         — op outputs match the declared dtypes
                                  (ids int32, bitset words uint32, flags
                                  bool) and the op traces at all;
  * ``jaxpr-dtype-width``       — no 64-bit value anywhere in the trace
                                  (run under ``--x64`` / JAX_ENABLE_X64=1 to
                                  make silent weak-type promotion visible);
  * ``jaxpr-banned-primitive``  — no host callbacks or device transfers in
                                  hot paths (`pure_callback`,
                                  `debug_callback`, `device_put`, ...).

Tracing is abstract — nothing executes, so the pass costs milliseconds per
op. New kernels get checked automatically: `register_backend` binds every
backend to a contract tuple (see `repro.core.backend.OpContract`).
"""
from __future__ import annotations

from typing import Iterable

import jax

from repro.analysis.staticcheck.findings import Finding, rule
from repro.core import backend as backend_lib

rule("jaxpr-out-dtype", "kernels",
     "op output dtype differs from its declared OpContract (or the op "
     "fails to trace)")
rule("jaxpr-dtype-width", "kernels",
     "64-bit value (float64/int64/uint64) inside a hot-path jaxpr")
rule("jaxpr-banned-primitive", "kernels",
     "host callback / transfer primitive inside a hot-path jaxpr")

WIDE_DTYPES = ("float64", "int64", "uint64", "complex128")
BANNED_PRIMITIVES = {
    "pure_callback",
    "io_callback",
    "debug_callback",
    "callback",
    "infeed",
    "outfeed",
    "device_put",
    "copy_to_host_async",
}


# ------------------------------------------------------------- jaxpr walking
def _jaxpr_of(obj):
    """Normalize ClosedJaxpr → Jaxpr; return None for non-jaxpr objects."""
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    return obj if hasattr(obj, "eqns") else None


def _sub_jaxprs(params: dict):
    for v in params.values():
        stack = [v]
        while stack:
            x = stack.pop()
            if isinstance(x, (tuple, list)):
                stack.extend(x)
                continue
            j = _jaxpr_of(x)
            if j is not None and hasattr(j, "eqns"):
                yield j


def iter_eqns(jaxpr):
    """Every equation in ``jaxpr`` and (recursively) its sub-jaxprs —
    pjit bodies, shard_map bodies, pallas kernel jaxprs, scan/cond branches."""
    j = _jaxpr_of(jaxpr)
    if j is None:
        return
    for eqn in j.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _aval_dtype(v) -> str | None:
    aval = getattr(v, "aval", None)
    dt = getattr(aval, "dtype", None)
    return str(dt) if dt is not None else None


def check_jaxpr(jaxpr, target: str) -> list[Finding]:
    """Walk one (closed) jaxpr: 64-bit avals and banned primitives."""
    findings: list[Finding] = []
    seen_wide: set[tuple[str, str]] = set()
    seen_banned: set[str] = set()
    for eqn in iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if prim in BANNED_PRIMITIVES and prim not in seen_banned:
            seen_banned.add(prim)
            findings.append(Finding(
                "jaxpr-banned-primitive", target, 0,
                f"primitive `{prim}` in a hot-path trace — host callbacks "
                "and transfers stall the device pipeline",
            ))
        for v in tuple(eqn.outvars) + tuple(eqn.invars):
            dt = _aval_dtype(v)
            if dt in WIDE_DTYPES and (dt, prim) not in seen_wide:
                seen_wide.add((dt, prim))
                findings.append(Finding(
                    "jaxpr-dtype-width", target, 0,
                    f"{dt} value at primitive `{prim}` — ids stay int32 and "
                    "bitsets stay uint32 (linear-space discipline); make the "
                    "narrow dtype explicit at the producer",
                ))
    return findings


# ----------------------------------------------------------- kernel op pass
def _trace_op(kern, contract) -> "jax.core.ClosedJaxpr":
    args, kw = contract.make_args()
    is_traced = [hasattr(a, "dtype") and hasattr(a, "shape") for a in args]
    traced = [a for a, t in zip(args, is_traced) if t]

    def call(*t):
        it = iter(t)
        full = [next(it) if flag else a for a, flag in zip(args, is_traced)]
        return getattr(kern, contract.op)(*full, **kw)

    return jax.make_jaxpr(call)(*traced)


def check_kernel_contracts(
    backends: Iterable[str] | None = None,
) -> list[Finding]:
    """Trace every contract-declared op on every registered backend and
    check declared output dtypes + jaxpr-wide rules."""
    findings: list[Finding] = []
    names = tuple(backends) if backends else backend_lib.available_backends()
    for name in names:
        kern = backend_lib.get_kernels(name)
        for contract in backend_lib.op_contracts(name):
            target = f"kernels:{name}:{contract.op}"
            try:
                jaxpr = _trace_op(kern, contract)
            except Exception as e:  # trace failure IS a contract violation
                findings.append(Finding(
                    "jaxpr-out-dtype", target, 0,
                    f"op failed to trace abstractly: {type(e).__name__}: {e}",
                ))
                continue
            outs = tuple(_aval_dtype(v) for v in jaxpr.jaxpr.outvars)
            if outs != contract.out_dtypes:
                findings.append(Finding(
                    "jaxpr-out-dtype", target, 0,
                    f"output dtypes {outs} != declared {contract.out_dtypes}",
                ))
            findings.extend(check_jaxpr(jaxpr, target))
    return findings
