"""Three-term roofline model from compiled dry-run artifacts.

    compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory     = HLO_bytes   / (chips × HBM_bw)
    collective = coll_bytes  / (chips × link_bw)

``compiled.cost_analysis()`` supplies FLOPs and bytes; collective bytes are
parsed from the optimized HLO text by summing the *result-shape* bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, with ring-cost multipliers (all-reduce counts 2×(n−1)/n,
all-gather/reduce-scatter (n−1)/n, permute 1×). XLA reports the per-device
partitioned module, so totals are already per-chip; the roofline divides by
chips only when given whole-program numbers (``per_device=False``).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

`engine_rooflines` points the same three-term model at the matcher itself:
per-executable FLOPs / peak bytes / collective bytes come from the
staticcheck cost model (`staticcheck/costmodel.py`) over the engine probe's
recorded entry points, giving per-entry-point ``bottleneck`` and
``roofline_fraction`` without any dry-run artifacts
(``benchmarks/bench_roofline.py`` reports them).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w\d.\-]*)\s*=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    bytes_by_kind: dict[str, float] = {}
    count_by_kind: dict[str, int] = {}
    ring = (n_devices - 1) / max(n_devices, 1)
    mult = {
        "all-reduce": 2.0 * ring,
        "all-gather": ring,
        "reduce-scatter": ring,
        "all-to-all": ring,
        "collective-permute": 1.0,
    }
    for m in _COLL_RE.finditer(hlo_text):
        _, dtype, dims, kind = m.groups()
        b = _shape_bytes(dtype, dims) * mult[kind]
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + b
        count_by_kind[kind] = count_by_kind.get(kind, 0) + 1
    return CollectiveStats(bytes_by_kind, count_by_kind)


@dataclasses.dataclass
class Roofline:
    flops: float             # per-device HLO flops
    hbm_bytes: float         # per-device bytes accessed
    collective_bytes: float  # per-device collective bytes moved
    n_chips: int
    model_flops: float = 0.0  # 6·N·D (or 6·N_active·D) whole-step model flops

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat/redundancy waste detector)."""
        total = self.flops * self.n_chips
        return (self.model_flops / total) if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step's time the dominant term says is 'useful
        peak': model_flops/chips/PEAK divided by the bounding term."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        ideal = (self.model_flops / self.n_chips) / PEAK_FLOPS
        return (ideal / bound) if bound > 0 else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


# ------------------------------------------------- matcher engine rooflines
def engine_rooflines(
    backends=None,
    kernels=None,
    *,
    scale: int = 1,
    n_chips: int | None = None,
) -> "dict[str, Roofline]":
    """Per-entry-point rooflines for the matcher engines, attributed from
    the staticcheck cost model — nothing executes beyond the tiny probe.

    `repro.analysis.staticcheck.engines.probe_traces` drives the real entry
    points (compile / run / stream / re-stream) on every (engine × kernels)
    combination and re-traces each cached executable;
    `staticcheck.costmodel.estimate` then walks the jaxprs for FLOPs, peak
    resident bytes and collective bytes. Per target (entry point), the
    per-metric max across its executables — the same aggregation the
    budgets pass uses — feeds one `Roofline`:

      * ``flops``            — the cost model's counted ops;
      * ``hbm_bytes``        — peak resident bytes, standing in for HBM
        traffic (a floor: every resident byte is written and read at least
        once; XLA fusion can only shrink it);
      * ``collective_bytes`` — ring-convention collective bytes;
      * ``model_flops``      — set equal to ``flops``: the matcher has no
        closed-form useful-flops model (no 6·N·D), and every counted op is
        algorithmically required at the jaxpr level, so
        ``roofline_fraction`` reads as "fraction of the bounding term the
        pure-compute time accounts for" (1.0 ⇔ compute-bound).

    Returns ``{target: Roofline}`` with targets like
    ``engine:local:jnp:match``.
    """
    import jax

    from repro.analysis.staticcheck import costmodel
    from repro.analysis.staticcheck import engines as _engines

    backends = tuple(backends or _engines.ENGINE_BACKENDS)
    kernels = tuple(kernels or _engines.KERNEL_BACKENDS)
    chips = n_chips if n_chips is not None else jax.device_count()

    worst: dict[str, dict] = {}
    for b in backends:
        for k in kernels:
            _, traces = _engines.probe_traces(b, k, scale=scale)
            for t in traces:
                est = costmodel.estimate(t.jaxpr, t.target)
                m = worst.setdefault(t.target, {
                    "flops": 0.0, "peak_bytes": 0.0, "collective_bytes": 0.0,
                })
                m["flops"] = max(m["flops"], est.flops)
                m["peak_bytes"] = max(m["peak_bytes"], est.peak_bytes)
                m["collective_bytes"] = max(
                    m["collective_bytes"], est.collective_bytes
                )
    return {
        target: Roofline(
            flops=m["flops"],
            hbm_bytes=m["peak_bytes"],
            collective_bytes=m["collective_bytes"],
            n_chips=chips,
            model_flops=m["flops"],
        )
        for target, m in sorted(worst.items())
    }


def model_flops_lm(cfg, batch: int, seq: int, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference (N = active params)."""
    n = cfg.n_active_params()
    tokens = batch * seq if kind in ("train", "prefill") else batch
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def model_flops_gnn(cfg, n_nodes: int, n_edges: int) -> float:
    """Per-layer: edges × d_hidden message work + nodes × MLP work, ×3 (train)."""
    d = cfg.d_hidden
    per_layer = 2.0 * n_edges * d * d + 2.0 * n_nodes * d * d * 2
    return 3.0 * cfg.n_layers * per_layer


def model_flops_recsys(cfg, batch: int, kind: str) -> float:
    m, d = cfg.n_sparse, cfg.embed_dim
    cin = 0.0
    h_prev = m
    for h in cfg.cin_layers:
        cin += 2.0 * h_prev * m * d * h
        h_prev = h
    dims = [m * d] + list(cfg.mlp_layers) + [1]
    dnn = sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:]))
    mult = 3.0 if kind == "train" else 1.0
    return mult * batch * (cin + dnn)
