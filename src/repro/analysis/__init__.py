from repro.analysis.roofline import (
    CollectiveStats,
    Roofline,
    parse_collectives,
)

__all__ = ["CollectiveStats", "Roofline", "parse_collectives"]
