"""Arch-aware logical→mesh axis rules.

The generic rule sets in ``launch.sharding`` assume every dimension divides
the mesh axis; real configs don't always (gemma has 8 heads on a 16-way
model axis, mixtral has 8 experts). ``make_rules`` builds the rule set per
(config × step kind × mesh), dropping or re-routing mappings that don't
divide — e.g. when experts can't shard over `model`, the expert FFN dim
takes `model` instead (so the parameters still shard 512 ways under
FSDP × TP).
"""
from __future__ import annotations

from repro.configs.base import GNNConfig, LMConfig, RecSysConfig
from repro.launch.mesh import mesh_axis_size


def _fits(dim: int, mesh, axes) -> bool:
    return dim % mesh_axis_size(mesh, axes) == 0


def make_rules(cfg, kind: str, mesh) -> dict:
    if isinstance(cfg, LMConfig):
        return _lm_rules(cfg, kind, mesh)
    if isinstance(cfg, GNNConfig):
        return _gnn_rules(cfg, kind, mesh)
    if isinstance(cfg, RecSysConfig):
        return _recsys_rules(cfg, kind, mesh)
    raise TypeError(type(cfg))


def _lm_rules(cfg: LMConfig, kind: str, mesh) -> dict:
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tp = "model"
    heads_ok = _fits(cfg.n_heads, mesh, tp)
    mlp_ok = _fits(cfg.d_ff, mesh, tp)
    expert_ok = cfg.moe is not None and _fits(cfg.moe.n_experts, mesh, tp)
    expert_mlp_ok = cfg.moe is not None and _fits(cfg.moe.d_ff_expert, mesh, tp)
    rules = {
        "layer": None,
        "batch": dp,
        "seq": None,
        "kv_seq": None,
        "embed": None,
        "heads": tp if heads_ok else None,
        "kv_heads": None,  # Nkv < TP for all assigned archs: replicate KV
        "mlp": tp if mlp_ok else None,
        "vocab": tp if _fits(cfg.vocab_size, mesh, tp) else None,
        "expert": tp if expert_ok else None,
        # if experts can't shard over model, shard the expert FFN dim instead
        "expert_mlp": None if expert_ok or not expert_mlp_ok else tp,
        # dispatch buffers always shard their capacity dim over DP: the
        # (E, C, D) buffer would otherwise replicate tens of GB per chip
        "expert_capacity": dp,
        "expert_group": dp,   # grouped-dispatch group axis (§Perf)
        "kv_block": tp,       # flash-decoding block axis (§Perf)
        "fsdp": dp,
        "lora": None,
    }
    if kind == "decode":
        # batch carries dp; kv cache length shards over the model axis
        # (decode attention is memory-bound: splitting S is flash-decoding)
        rules["kv_seq"] = tp
    if kind == "decode_long":
        # batch=1: everything rides on the sequence axis
        rules["batch"] = None
        rules["kv_seq"] = dp + (tp,)
    if (
        kind in ("prefill", "decode", "decode_long")
        and cfg.inference_param_sharding == "tp_replicated"
    ):
        # §Perf 2: inference keeps weights TP-sharded and DP-replicated —
        # no per-step FSDP gathers (they dominate decode collectives);
        # experts spread over data×model when the count divides
        rules["fsdp"] = None
        if cfg.moe is not None and _fits(cfg.moe.n_experts, mesh, dp + (tp,)):
            rules["expert"] = dp + (tp,)
    return rules


def _gnn_rules(cfg: GNNConfig, kind: str, mesh) -> dict:
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    return {
        "layer": None,
        "nodes": all_axes,
        "edges": all_axes,
        "feat": None,
        "hidden": None,
        "classes": None,
        "graph_batch": None,  # per-graph labels are tiny (≤ batch count)
        "fsdp": None,
    }


def _recsys_rules(cfg: RecSysConfig, kind: str, mesh) -> dict:
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tp = "model"
    return {
        "batch": dp,
        "field": None,
        "rows": tp if _fits(cfg.vocab_per_field, mesh, tp) else None,
        "embed": None,
        "mlp": tp if all(m % mesh_axis_size(mesh, tp) == 0 for m in cfg.mlp_layers) else None,
        "cin": tp if all(c % mesh_axis_size(mesh, tp) == 0 for c in cfg.cin_layers) else None,
        "candidates": dp + (tp,),
        "fsdp": None,
    }
