from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.sharding import axis_rules, logical, spec_for

__all__ = [
    "make_host_mesh",
    "make_production_mesh",
    "axis_rules",
    "logical",
    "spec_for",
]
