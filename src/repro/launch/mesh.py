"""Production meshes.

Single pod: (data=16, model=16) — 256 chips (one TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across 2 pods; the
``pod`` axis carries data parallelism with compressed gradients by default
and can alternatively serve as a pipeline axis (launch/pipeline.py).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, axis: str = "data"):
    """A small CPU mesh over however many (host) devices exist — used by the
    distributed matcher tests and examples."""
    devs = jax.devices()
    n = n or len(devs)
    return jax.make_mesh((n,), (axis,), devices=devs[:n])


def mesh_axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    out = 1
    for n in names:
        if n in mesh.shape:
            out *= mesh.shape[n]
    return out
