"""Logical-axis sharding rules (MaxText-style).

Model code annotates activations with *logical* axis names
(``logical(x, "batch", "seq", "embed")``); a rule set maps logical names to
mesh axes per (arch family × shape kind). Params carry logical axes in their
schema (see models/schema.py) and get their NamedSharding the same way.

Outside a rules context everything is a no-op, so single-device smoke tests
never touch sharding machinery.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_rules() -> dict[str, tuple[str, ...] | str | None] | None:
    return getattr(_state, "rules", None)


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: dict[str, tuple[str, ...] | str | None]):
    """Activate a logical→mesh axis mapping (and its mesh)."""
    old_rules = getattr(_state, "rules", None)
    old_mesh = getattr(_state, "mesh", None)
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = old_rules, old_mesh


def spec_for(axes: tuple[str | None, ...]) -> P:
    """Map logical axis names to a PartitionSpec under the active rules."""
    rules = current_rules()
    if rules is None:
        return P()
    out = []
    used: set[str] = set()
    for name in axes:
        m = rules.get(name) if name is not None else None
        if m is None:
            out.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(a for a in ms if a not in used)
        used.update(ms)
        if not ms:
            out.append(None)  # all mesh axes already consumed by earlier dims
        else:
            out.append(ms if len(ms) != 1 else ms[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def logical(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain an activation's sharding by logical axis names (no-op
    without active rules)."""
    mesh = current_mesh()
    if mesh is None or current_rules() is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(axes))
    )


def named_sharding(axes: tuple[str | None, ...]) -> NamedSharding | None:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(axes))


# ---------------------------------------------------------------- rule sets
def lm_rules(kind: str) -> dict:
    """kind: train | prefill | decode.

    data-parallel/FSDP over (pod, data); tensor-parallel over model.
    Sequence (context) parallelism shards long sequences over `data` in
    prefill. Experts shard over `model` (EP).
    """
    base = {
        "batch": ("pod", "data"),
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "qkv": None,
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "expert_mlp": None,
        "fsdp": ("pod", "data"),     # param sharding axis (ZeRO-3 style)
        "seq": None,
        "kv_seq": None,
        "lora": None,
    }
    if kind == "prefill":
        base["seq"] = ("pod", "data")   # sequence parallelism
        base["batch"] = None
    if kind == "decode":
        base["kv_seq"] = None
    return base


def gnn_rules(kind: str) -> dict:
    return {
        "graph_batch": ("pod", "data"),
        "nodes": ("pod", "data"),
        "edges": ("pod", "data"),
        "feat": None,
        "hidden": "model",
        "fsdp": None,
        "classes": None,
    }


def recsys_rules(kind: str) -> dict:
    return {
        "batch": ("pod", "data"),
        "field": None,
        "rows": ("pod", "data", "model") if kind != "train" else ("model",),
        "embed": None,
        "mlp": "model",
        "cin": "model",
        "candidates": ("pod", "data", "model"),
        "fsdp": None,
    }
