"""Serving launcher: subgraph-query serving (the paper's workload) or LM
decode serving, selected by --arch family.

    PYTHONPATH=src python -m repro.launch.serve --arch stwig --n-queries 20
    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import GraphSession
from repro.configs import get
from repro.configs.base import LMConfig
from repro.graphstore import generators
from repro.models import transformer as tf
from repro.workloads import dfs_query


def serve_stwig(args) -> None:
    cfg = get("stwig").smoke() if args.smoke else get("stwig").config
    n = min(cfg.n_nodes, args.max_nodes)
    print(f"loading {n}-node graph ...")
    g = generators.rmat(n, cfg.avg_degree * n, cfg.n_labels, seed=0)
    session = GraphSession.open(g, backend="local")
    rng = np.random.default_rng(0)

    served = 0
    t0 = time.perf_counter()
    for _ in range(args.n_queries):
        q = dfs_query(g, rng, 6)
        if q is None:
            continue
        res = session.run(
            q,
            max_matches=cfg.max_matches,
            adaptive=False,
            deadline_s=args.deadline_ms / 1e3 if args.deadline_ms else None,
        )
        served += 1
        # a partial answer must say so (and why): first-K truncation has no
        # degrade reason, a guard trip / shard fault carries a typed one
        status = ""
        if not res.complete:
            status = f"  [partial: {res.stats.degrade_reason or 'overflow'}]"
        print(
            f"  query served: {res.n_matches} matches in "
            f"{res.stats.time_s*1e3:.0f} ms{status}"
        )
    print(f"{served} queries in {time.perf_counter()-t0:.1f}s "
          f"(cache: {session.cache.hits} hits / {session.cache.misses} misses)")


def serve_lm(args) -> None:
    entry = get(args.arch)
    cfg: LMConfig = entry.smoke()
    params = tf.init(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (args.batch, 8), 0, cfg.vocab_size)
    logits, cache = tf.prefill(cfg, params, prompt)
    cache_full = tf.init_cache(cfg, args.batch, 8 + args.tokens)
    data = tuple(
        jax.lax.dynamic_update_slice(z, c.astype(z.dtype), (0,) * z.ndim)
        for z, c in zip(cache_full.data, cache.data)
    )
    cache = cache_full.replace_data(data)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    step = jax.jit(lambda p, c, t, pos: tf.decode_step(cfg, p, c, t, pos))
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, cache = step(params, cache, tok, jnp.int32(8 + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens × batch {args.batch} in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.0f} tok/s on CPU, smoke config)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stwig")
    ap.add_argument("--n-queries", type=int, default=10)
    ap.add_argument("--max-nodes", type=int, default=50_000)
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-query deadline (0 = none); expired queries "
                    "return partial results marked [partial: deadline]")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()
    if args.arch == "stwig":
        serve_stwig(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
