"""Serving launcher: subgraph-query serving (the paper's workload) or LM
decode serving, selected by --arch family.

    PYTHONPATH=src python -m repro.launch.serve --arch stwig --n-queries 20
    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import GraphSession, summarize_outcomes
from repro.configs import get
from repro.configs.base import LMConfig
from repro.graphstore import generators
from repro.models import transformer as tf
from repro.workloads import dfs_query


def serve_stwig(args) -> None:
    cfg = get("stwig").smoke() if args.smoke else get("stwig").config
    n = min(cfg.n_nodes, args.max_nodes)
    print(f"loading {n}-node graph ...")
    g = generators.rmat(n, cfg.avg_degree * n, cfg.n_labels, seed=0)
    session = GraphSession.open(g, backend="local")
    rng = np.random.default_rng(0)
    workload = [q for q in (dfs_query(g, rng, 6) for _ in range(args.n_queries))
                if q is not None]

    server = session.serve(
        max_inflight=args.max_inflight,
        max_matches=cfg.max_matches,
        deadline_s=args.deadline_ms / 1e3 if args.deadline_ms else None,
    )
    t0 = time.perf_counter()
    outcomes = server.serve(workload)
    wall = time.perf_counter() - t0
    for o in outcomes:
        # a partial answer must say so (and why): first-K truncation has no
        # degrade reason, a guard trip / shard fault carries a typed one
        status = ""
        if o.status != "served":
            status = f"  [{o.status}: {o.stats.degrade_reason or o.error or 'overflow'}]"
        ttfp = "-" if o.ttfp_s is None else f"{o.ttfp_s*1e3:.0f} ms"
        print(f"  {o.n_matches} matches, first page in {ttfp}{status}")
    # a query counts as served only when it completed cleanly — guard
    # trips/overflows are partial, per-query exceptions are failed
    s = summarize_outcomes(outcomes)
    print(f"{s['served']} served / {s['partial']} partial / {s['failed']} "
          f"failed in {wall:.1f}s "
          f"({len(outcomes)/wall:.2f} qps over {server.stats.join_quanta} "
          f"block-join quanta, {server.stats.global_degradations} global "
          f"degradations; cache: {session.cache.hits} hits / "
          f"{session.cache.misses} misses)")


def serve_lm(args) -> None:
    entry = get(args.arch)
    cfg: LMConfig = entry.smoke()
    params = tf.init(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (args.batch, 8), 0, cfg.vocab_size)
    logits, cache = tf.prefill(cfg, params, prompt)
    cache_full = tf.init_cache(cfg, args.batch, 8 + args.tokens)
    data = tuple(
        jax.lax.dynamic_update_slice(z, c.astype(z.dtype), (0,) * z.ndim)
        for z, c in zip(cache_full.data, cache.data)
    )
    cache = cache_full.replace_data(data)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    step = jax.jit(lambda p, c, t, pos: tf.decode_step(cfg, p, c, t, pos))
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, cache = step(params, cache, tok, jnp.int32(8 + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens × batch {args.batch} in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.0f} tok/s on CPU, smoke config)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stwig")
    ap.add_argument("--n-queries", type=int, default=10)
    ap.add_argument("--max-nodes", type=int, default=50_000)
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-query deadline (0 = none); expired queries "
                    "return partial results marked [partial: deadline]")
    ap.add_argument("--max-inflight", type=int, default=8,
                    help="concurrent queries the server interleaves "
                    "block-join quanta across (continuous batching)")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()
    if args.arch == "stwig":
        serve_stwig(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
