"""GPipe-style pipeline parallelism over a mesh axis (the multi-pod option).

At 2 pods the cross-pod axis can carry either data parallelism (default
rules) or a pipeline: each pod holds a contiguous stage of layers and
activations travel pod→pod with `ppermute` while microbatches fill the
pipeline (classic GPipe schedule, M + S − 1 ticks, bubble fraction
(S−1)/(M+S−1)).

This module is deliberately model-agnostic: ``stage_fn(stage_params, x)``
applies one stage. ``gpipe`` wraps it in shard_map over the pipe axis;
weights are pre-split with a leading stage axis sharded on that axis, so
each pod only ever holds its own stage (PP memory scaling).

Inference-friendly forward pipeline (training with PP composes with
jax.grad through the scan; the reverse pipeline reuses the same permute
pattern in the transposed direction automatically).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def gpipe(
    stage_fn,
    mesh: Mesh,
    *,
    axis: str = "pod",
    data_axes: tuple[str, ...] = (),
):
    """Build a pipelined apply: (stage_params, x_microbatched) → y.

    stage_params: pytree with leading dim = n_stages (sharded over ``axis``).
    x: (n_micro, mb, ...) microbatched input (replicated over ``axis``,
    optionally sharded over ``data_axes`` on the mb dim).
    Returns y (n_micro, mb, ...) replicated over ``axis``.
    """
    n_stages = mesh.shape[axis]

    def body(params, x):
        # params: leading dim 1 (my stage); x: full (M, mb, ...)
        my_params = jax.tree.map(lambda a: a[0], params)
        M = x.shape[0]
        S = n_stages
        stage = lax.axis_index(axis)
        T = M + S - 1
        fwd = [(i, i + 1) for i in range(S - 1)]

        y0 = jnp.zeros_like(stage_fn(my_params, x[0]))

        def tick(carry, t):
            buf, outs = carry
            mb = t - stage
            active = (mb >= 0) & (mb < M)
            xin = jnp.where(
                stage == 0, x[jnp.clip(mb, 0, M - 1)], buf
            )
            y = stage_fn(my_params, xin)
            y = jnp.where(active, y, jnp.zeros_like(y))
            slot = jnp.clip(mb, 0, M - 1)
            outs = outs.at[slot].set(
                jnp.where(active & (stage == S - 1), y, outs[slot])
            )
            buf_next = lax.ppermute(y, axis, fwd) if S > 1 else y
            return (buf_next, outs), None

        outs0 = jnp.zeros((M,) + y0.shape, y0.dtype)
        (_, outs), _ = lax.scan(tick, (y0, outs0), jnp.arange(T))
        # outs is zero everywhere except the last stage → psum broadcasts it
        return lax.psum(outs, axis) if S > 1 else outs

    # params sharded over the pipe axis; activations replicated over it
    in_specs = (P(axis), P())
    out_specs = P()
    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )
    )


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead: (S−1)/(M+S−1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
