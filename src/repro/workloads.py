"""Query-workload generators (paper §6.1).

The paper evaluates with queries sampled from the data graph (DFS queries —
guaranteed at least one match) and random label/topology queries. These
used to live in ``benchmarks.common``, which the serving launcher imported
at runtime — a layering violation; they are library code and live here now.
``benchmarks.common`` and ``tests/helpers.py`` re-export them.
"""
from __future__ import annotations

import numpy as np

from repro.core.query import QueryGraph
from repro.graphstore.csr import Graph


def dfs_query(g: Graph, rng: np.random.Generator, n_nodes: int) -> QueryGraph | None:
    """Paper §6.1 DFS query: traverse from a random node, keep the first
    ``n_nodes`` visited (None if the start node is too isolated)."""
    start = int(rng.integers(g.n_nodes))
    nodes, edges, seen = [start], [], {start}
    stack = [start]
    while stack and len(nodes) < n_nodes:
        v = stack.pop()
        for u in g.neighbors(v):
            u = int(u)
            if u not in seen and len(nodes) < n_nodes:
                seen.add(u)
                nodes.append(u)
                edges.append((v, u))
                stack.append(u)
    if len(nodes) < 2:
        return None
    remap = {v: i for i, v in enumerate(nodes)}
    return QueryGraph.build(
        [int(g.labels[v]) for v in nodes],
        [(remap[a], remap[b]) for a, b in edges],
    )


def path_query(g: Graph, rng: np.random.Generator, n_nodes: int) -> QueryGraph | None:
    """A simple-path query sampled from the data graph (always matchable,
    like `dfs_query`, but guaranteed path topology). Paths of ≥4 nodes
    decompose into ≥2 STwigs, so they exercise the join phase — `dfs_query`
    often lands on a star, which a single STwig covers."""
    v = int(rng.integers(g.n_nodes))
    nodes = [v]
    while len(nodes) < n_nodes:
        nbrs = [int(u) for u in g.neighbors(nodes[-1]) if int(u) not in nodes]
        if not nbrs:
            return None
        nodes.append(nbrs[int(rng.integers(len(nbrs)))])
    return QueryGraph.build(
        [int(g.labels[v]) for v in nodes],
        [(i, i + 1) for i in range(n_nodes - 1)],
    )


def random_query(
    n_nodes: int, n_edges: int, n_labels: int, rng: np.random.Generator
) -> QueryGraph:
    """Random connected query: a random tree plus extra random edges, with
    uniform random labels."""
    edges = [(int(rng.integers(i)), i) for i in range(1, n_nodes)]
    seen = {(min(a, b), max(a, b)) for a, b in edges}
    tries = 0
    while len(edges) < n_edges and tries < 10 * n_edges:
        a, b = rng.integers(n_nodes, size=2)
        tries += 1
        key = (min(a, b), max(a, b))
        if a != b and key not in seen:
            seen.add(key)
            edges.append((int(a), int(b)))
    return QueryGraph.build(
        rng.integers(0, n_labels, n_nodes).astype(int).tolist(), edges
    )


def mixed_workload(
    g: Graph,
    n_queries: int,
    *,
    n_labels: int,
    rng: np.random.Generator,
    min_nodes: int = 4,
    max_nodes: int = 8,
) -> list[QueryGraph]:
    """The serving mix used in examples/benchmarks: alternate DFS (always
    matchable) and random (often empty) queries."""
    out: list[QueryGraph] = []
    for i in range(n_queries):
        nq = int(rng.integers(min_nodes, max_nodes))
        q = (
            dfs_query(g, rng, nq)
            if i % 2 == 0
            else random_query(nq, 8, n_labels, rng)
        )
        if q is not None:
            out.append(q)
    return out
