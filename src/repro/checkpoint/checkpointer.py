"""Sharded checkpointing with async save, restart, and elastic resharding.

Layout: ``<dir>/step_<n>/{manifest.json, <leaf-path>.npy ...}``. Leaves are
gathered to host and written per-tensor, so a checkpoint written on an
N-device mesh restores onto an M-device mesh (elastic scaling: survivors of
a failed pod resume on a smaller mesh by re-running ``restore`` with the new
mesh's shardings — see runtime/fault_tolerance.py). At true 1000-node scale
the same layout shards each tensor's write across hosts; the manifest format
already records per-leaf shape/dtype so that change is local to ``save``.

Async mode hands the host arrays to a writer thread; ``wait()`` joins before
the next save (bounded staleness of one checkpoint).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((name, leaf))
    return out


@dataclasses.dataclass
class Checkpointer:
    directory: str | pathlib.Path
    keep: int = 3
    async_save: bool = True

    def __post_init__(self):
        self.directory = pathlib.Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> None:
        self.wait()
        host = [(n, np.asarray(jax.device_get(x))) for n, x in _flatten_with_paths(tree)]
        treedef = jax.tree_util.tree_structure(tree)

        def write():
            d = self.directory / f"step_{step:08d}"
            tmp = self.directory / f".tmp_step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "leaves": [], "treedef": str(treedef)}
            for name, arr in host:
                fn = name.replace("/", "__") + ".npy"
                np.save(tmp / fn, arr)
                manifest["leaves"].append(
                    {"name": name, "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
                )
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if d.exists():
                shutil.rmtree(d)
            tmp.rename(d)  # atomic publish: partial checkpoints never visible
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.directory.glob("step_*"))
        for old in steps[: -self.keep]:
            shutil.rmtree(old)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        self.wait()  # join any in-flight async save first
        steps = sorted(self.directory.glob("step_*"))
        return int(steps[-1].name.split("_")[1]) if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: optional matching pytree of
        NamedShardings for the *current* mesh (elastic restore)."""
        self.wait()
        d = self.directory / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_name = {m["name"]: m for m in manifest["leaves"]}
        names = [n for n, _ in _flatten_with_paths(like)]
        leaves = []
        for n in names:
            m = by_name[n]
            leaves.append(np.load(d / m["file"]))
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings
            )
        else:
            tree = jax.tree.map(jax.device_put, tree)
        return tree
