"""Deterministic synthetic data pipelines (offline container).

Every generator is a pure function of (seed, step) so that checkpoint/restart
resumes with bitwise-identical batches — the property the fault-tolerance
tests assert. Real deployments swap these for file-backed loaders with the
same signatures; batches are host numpy (device placement happens in the
train loop with the mesh's input shardings).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import GNNConfig, LMConfig, RecSysConfig
from repro.graphstore.csr import Graph
from repro.graphstore.sampler import NeighborSampler
from repro.models.gnn import GraphBatch


def lm_batch(cfg: LMConfig, batch: int, seq: int, *, seed: int, step: int) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # zipfian tokens: realistic softmax/label statistics
    z = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
    return {"tokens": (z % cfg.vocab_size).astype(np.int32)}


def gnn_full_batch(
    cfg: GNNConfig, g: Graph, *, n_classes: int, seed: int
) -> GraphBatch:
    """Full-graph training batch straight from a graphstore Graph."""
    rng = np.random.default_rng(seed)
    N, E = g.n_nodes, g.n_edges
    src = np.repeat(np.arange(N, dtype=np.int32), np.diff(g.indptr))
    dst = g.indices.astype(np.int32)
    return GraphBatch(
        node_feat=rng.normal(size=(N, cfg.d_in)).astype(np.float32),
        edge_src=src,
        edge_dst=dst,
        node_mask=np.ones(N, bool),
        edge_mask=np.ones(E, bool),
        edge_feat=rng.normal(size=(E, cfg.d_edge)).astype(np.float32)
        if cfg.d_edge
        else None,
        node_pos=rng.normal(size=(N, 3)).astype(np.float32)
        if cfg.kind == "egnn"
        else None,
        graph_id=None,
        n_graphs=1,
        labels=rng.normal(size=(N,)).astype(np.float32)
        if cfg.task == "regression"
        else rng.integers(0, n_classes, N).astype(np.int32),
        label_mask=np.ones(N, bool),
    )


def gnn_minibatch(
    cfg: GNNConfig,
    g: Graph,
    sampler: NeighborSampler,
    *,
    batch_nodes: int,
    n_classes: int,
    seed: int,
    step: int,
) -> GraphBatch:
    """Sampled k-hop minibatch (the minibatch_lg regime)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    seeds = rng.choice(g.n_nodes, size=batch_nodes, replace=False)
    sub = sampler.sample(seeds)
    N = sub.node_cap
    feat_rng = np.random.default_rng(np.random.SeedSequence([seed, 7]))
    # features keyed by global node id hash → consistent across batches
    feats = feat_rng.normal(size=(1, cfg.d_in)).astype(np.float32)
    node_feat = np.tile(feats, (N, 1)) * (1 + (sub.nodes[:, None] % 13) / 13.0)
    labels = (np.maximum(sub.nodes, 0) % n_classes).astype(np.int32)
    return GraphBatch(
        node_feat=node_feat.astype(np.float32),
        edge_src=sub.edge_src,
        edge_dst=sub.edge_dst,
        node_mask=sub.nodes >= 0,
        edge_mask=sub.edge_mask,
        edge_feat=np.zeros((sub.edge_cap, cfg.d_edge), np.float32)
        if cfg.d_edge
        else None,
        node_pos=np.zeros((N, 3), np.float32) if cfg.kind == "egnn" else None,
        graph_id=None,
        n_graphs=1,
        labels=labels,
        label_mask=sub.seed_mask,
    )


def recsys_batch(
    cfg: RecSysConfig, batch: int, *, seed: int, step: int
) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    ids = rng.zipf(1.2, size=(batch, cfg.n_sparse, cfg.bag_size))
    ids = (ids % cfg.vocab_per_field).astype(np.int32)
    mask = rng.random((batch, cfg.n_sparse, cfg.bag_size)) < 0.7
    mask[..., 0] = True  # at least one id per bag
    # labels correlated with a random linear model over first ids
    w = np.random.default_rng(seed).normal(size=cfg.n_sparse)
    score = (ids[..., 0] % 97 / 97.0) @ w
    labels = (score > np.median(score)).astype(np.int32)
    return {"ids": ids, "bag_mask": mask, "labels": labels}
