from repro.data.pipeline import (
    gnn_full_batch,
    gnn_minibatch,
    lm_batch,
    recsys_batch,
)

__all__ = ["gnn_full_batch", "gnn_minibatch", "lm_batch", "recsys_batch"]
